//! A compiled sort executable plus typed marshalling.

use anyhow::{ensure, Context};

use super::artifact::{ArtifactMeta, Dtype};

/// One compiled (PJRT-loaded) sort artifact, ready to execute.
pub struct SortExecutor {
    /// The artifact this executor was compiled from.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl SortExecutor {
    /// Compile `hlo_text_path` on `client`. Expensive (XLA compilation);
    /// the [`super::Registry`] caches the result per artifact.
    pub fn compile(
        client: &xla::PjRtClient,
        meta: ArtifactMeta,
        hlo_text_path: &std::path::Path,
    ) -> anyhow::Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_text_path
                .to_str()
                .context("artifact path is not valid UTF-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {hlo_text_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", meta.name))?;
        Ok(Self { meta, exe })
    }

    /// Sort a full `(batch, n)` buffer of u32 keys, row-major. Returns the
    /// sorted rows in the same layout. This is the hot path: one
    /// host→device copy, one execution, one device→host copy.
    pub fn sort_u32(&self, rows: &[u32]) -> anyhow::Result<Vec<u32>> {
        ensure!(
            self.meta.dtype == Dtype::U32,
            "artifact {} holds {:?} keys",
            self.meta.name,
            self.meta.dtype
        );
        self.execute_raw(bytes_of(rows))
            .map(|bytes| from_bytes::<u32>(&bytes))
    }

    /// Sort `(batch, n)` i32 keys.
    pub fn sort_i32(&self, rows: &[i32]) -> anyhow::Result<Vec<i32>> {
        ensure!(self.meta.dtype == Dtype::I32, "dtype mismatch");
        self.execute_raw(bytes_of(rows))
            .map(|bytes| from_bytes::<i32>(&bytes))
    }

    /// Sort `(batch, n)` f32 keys (finite values only — NaN ordering is
    /// not defined for the min/max network; see DESIGN.md §6).
    pub fn sort_f32(&self, rows: &[f32]) -> anyhow::Result<Vec<f32>> {
        ensure!(self.meta.dtype == Dtype::F32, "dtype mismatch");
        self.execute_raw(bytes_of(rows))
            .map(|bytes| from_bytes::<f32>(&bytes))
    }

    fn execute_raw(&self, data: &[u8]) -> anyhow::Result<Vec<u8>> {
        let (b, n) = (self.meta.batch, self.meta.n);
        ensure!(
            data.len() == b * n * self.meta.dtype.size(),
            "artifact {} wants {}x{} ({} bytes), got {} bytes",
            self.meta.name,
            b,
            n,
            b * n * self.meta.dtype.size(),
            data.len()
        );
        let ty = match self.meta.dtype {
            Dtype::U32 => xla::ElementType::U32,
            Dtype::I32 => xla::ElementType::S32,
            Dtype::F32 => xla::ElementType::F32,
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(ty, &[b, n], data)
            .map_err(|e| anyhow::anyhow!("literal creation: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.meta.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?
            // aot.py lowers with return_tuple=True → 1-tuple.
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let vec_len = b * n;
        match self.meta.dtype {
            Dtype::U32 => {
                let v = out
                    .to_vec::<u32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                ensure!(v.len() == vec_len, "result length {} != {vec_len}", v.len());
                Ok(bytes_of(&v).to_vec())
            }
            Dtype::I32 => {
                let v = out
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                ensure!(v.len() == vec_len, "result length {} != {vec_len}", v.len());
                Ok(bytes_of(&v).to_vec())
            }
            Dtype::F32 => {
                let v = out
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                ensure!(v.len() == vec_len, "result length {} != {vec_len}", v.len());
                Ok(bytes_of(&v).to_vec())
            }
        }
    }
}

/// Reinterpret a plain-data slice as bytes.
fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) }
}

/// Reinterpret bytes as a plain-data vector (copies).
fn from_bytes<T: Copy>(bytes: &[u8]) -> Vec<T> {
    let n = bytes.len() / std::mem::size_of::<T>();
    let mut out = Vec::<T>::with_capacity(n);
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr().cast::<u8>(), bytes.len());
        out.set_len(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip_u32() {
        let xs = [0xDEAD_BEEFu32, 1, u32::MAX];
        let b = bytes_of(&xs);
        assert_eq!(b.len(), 12);
        let back: Vec<u32> = from_bytes(b);
        assert_eq!(back, xs);
    }

    #[test]
    fn byte_roundtrip_f32() {
        let xs = [1.5f32, -0.0, f32::INFINITY];
        let back: Vec<f32> = from_bytes(bytes_of(&xs));
        assert_eq!(back[0], 1.5);
        assert!(back[1].is_sign_negative());
        assert_eq!(back[2], f32::INFINITY);
    }
}
