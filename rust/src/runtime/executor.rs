//! A loaded sort artifact plus typed marshalling, executed natively with
//! a **plan/execute split**.
//!
//! The original design compiled `artifacts/*.hlo.txt` with the `xla`
//! crate's PJRT CPU client. That crate is not vendored in this offline
//! environment, so the executor is a deterministic **native-CPU
//! fallback** organised the way a real PJRT backend is:
//!
//! * **Plan (compile time).** [`SortExecutor::compile`] loads and
//!   validates the artifact's HLO text (dtype+shape token and module
//!   sanity — catching manifest/file drift at load time, exactly where
//!   PJRT compilation would fail) and compiles a **launch program** —
//!   [`Network::launches`] / [`Network::merge_launches`] at a
//!   configurable [`PlanConfig`] `{ variant, block }` — into an
//!   [`ExecutionPlan`]. This happens once per artifact, cached by the
//!   registry. The default plan is `Optimized` at an L1-sized block, so
//!   the executor runs the paper's two §4 optimizations natively:
//!   `BlockFused` launches keep a cache-resident tile hot across all
//!   small strides (one read+write of the row per fused group instead of
//!   one per step), and `GlobalDoubleStep` launches pair two global
//!   strides in registers, halving the remaining full-row passes.
//! * **Execute (request time).** The `sort_*` entry points are a pure
//!   walk over the launch program: no schedule re-derivation per row per
//!   call. The `(B, N)` buffer is cut into tiles of
//!   `PlanConfig::interleave` rows, and each tile executes every launch
//!   **across its rows at once** in an element-major interleaved layout
//!   ([`ExecutionPlan::run_tile`] →
//!   [`crate::sort::network::run_launch_interleaved`]) — the inner
//!   compare-exchange loops become long branchless stride-1 sweeps, one
//!   SIMD lane per row, the CPU translation of the paper's one-warp-lane-
//!   per-element geometry (`interleave: 1` keeps the scalar
//!   [`crate::sort::network::run_launch`] walk). When the executor holds
//!   a shared [`ThreadPool`] (threaded through
//!   [`crate::runtime::Registry`] from the device-host config), tiles
//!   are dispatched via [`ThreadPool::run_scoped`], so tiles sort in
//!   parallel on top of the per-tile lane parallelism. A panicking tile
//!   task fails the batch with an error instead of poisoning the pool.
//!
//! The executor honours the full artifact contract the integration tests
//! pin down — ascending/descending, u32/i32/f32, sort and merge kinds,
//! MAX-padding semantics — and is bit-exact with the CPU substrates (and
//! with its own serial path; property-tested below). Swapping a real
//! PJRT backend in later replaces the plan walk, not the module
//! boundary: same constructor, same `sort_*` entry points.

use std::path::Path;
use std::sync::Arc;

use crate::sort::network::{
    run_launch_counting_isa, run_launch_interleaved_isa, Launch, Network, Variant,
};
use crate::sort::simd::{KernelChoice, KernelIsa};
use crate::sort::SortKey;
use crate::util::error::Context;
use crate::util::threadpool::{ScopedJob, ThreadPool};

use super::artifact::{ArtifactKind, ArtifactMeta, Dtype};

/// Default fused-tile block for native execution, in keys: 4096 u32 keys
/// = 16 KiB — half of a typical 32 KiB L1d, leaving room for the stack
/// and prefetch; also exactly `python/compile/model.py::DEFAULT_BLOCK`
/// (the paper's K10 48 KiB shared-memory tile: 48 KiB / 2 buffers / 4 B).
pub const DEFAULT_PLAN_BLOCK: usize = 4096;

/// Default batch-interleave width R (rows per interleaved tile): 8 u32
/// lanes = one 32-byte AVX2 vector per compare-exchange operand, the
/// narrowest width that keeps the small-stride sweeps (length `j * R`)
/// vector-saturated down to stride 1. Per-host sweeps pick better values
/// (`bitonic-tpu tune`); 1 disables interleaving (scalar row-at-a-time).
pub const DEFAULT_PLAN_INTERLEAVE: usize = 8;

/// How [`ExecutionPlan`] compiles the network into launches — which of
/// the paper's §4 optimizations the native executor runs, and the fused
/// tile size — plus how the executor *drives* the plan over a batch (the
/// batch-interleave width). The plan-level analogue of picking a kernel
/// variant and launch geometry on the GPU; `Variant::Basic` at
/// `interleave: 1` degenerates to the serial one-pass-per-step walk (the
/// reference schedule the property tests compare against).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanConfig {
    /// Launch-fusion variant (paper Table 1 columns).
    pub variant: Variant,
    /// Fused-tile capacity in keys (power of two >= 2); clamped to the
    /// row length at compile time.
    pub block: usize,
    /// Batch-interleave width R (>= 1): the executor cuts each `(B, N)`
    /// batch into tiles of R rows and runs every launch *across* the
    /// tile's rows in an element-major interleaved layout — one SIMD lane
    /// per row, the CPU translation of the paper's one-thread-per-element
    /// SIMT geometry. 1 = scalar row-at-a-time execution (the PR 3 path).
    /// At dispatch time the width is clamped to the batch size and, when
    /// an execution pool is attached, narrowed so the batch still yields
    /// at least one tile per worker (threads scale better than lanes).
    pub interleave: usize,
    /// Comparator instruction set ([`crate::sort::simd`]): `Auto`
    /// resolves once at plan-compile time (AVX2 when the `simd` feature
    /// is built and the host supports it, else the scalar kernels); a
    /// fixed ISA pins the sweeps for ablations and autotuned profiles.
    /// The launch structure, pass counts and disjointness proofs are
    /// identical for every ISA — only instruction selection changes.
    pub kernel: KernelChoice,
}

impl Default for PlanConfig {
    fn default() -> Self {
        Self {
            variant: Variant::Optimized,
            block: DEFAULT_PLAN_BLOCK,
            interleave: DEFAULT_PLAN_INTERLEAVE,
            kernel: KernelChoice::Auto,
        }
    }
}

/// The compiled launch program of one artifact: the exact pass sequence
/// ([`Launch`] list) the configured variant executes, plus the pre/post
/// row transforms the artifact kind and direction require. Plain data,
/// `Sync` — shared read-only by every row task. This is the seam a
/// future PJRT backend replaces: planning stays, the walk becomes a
/// device dispatch.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    /// Row length `n` the plan was built for.
    n: usize,
    /// What the program computes: a full sort or the final merge phase.
    kind: ArtifactKind,
    /// Reverse the row's second half before the launches (merge
    /// artifacts: two ascending halves form a bitonic sequence).
    reverse_tail: bool,
    /// Launch program, execution order. Expanding each launch via
    /// [`Launch::steps`] reproduces the flat `(phase_len, stride)`
    /// schedule exactly (the invariant pinned in `sort::network` tests).
    launches: Vec<Launch>,
    /// Reverse the whole row after the launches (descending artifacts).
    reverse_output: bool,
    /// The configuration the program was compiled at.
    config: PlanConfig,
    /// The comparator ISA [`PlanConfig::kernel`] resolved to on this
    /// host, fixed at compile time so every row/tile of the plan runs
    /// the same kernels.
    isa: KernelIsa,
}

impl ExecutionPlan {
    /// Compile the default launch program ([`PlanConfig::default`]:
    /// `Optimized`, L1-sized block) for an artifact shape.
    pub fn new(kind: ArtifactKind, n: usize, descending: bool) -> Self {
        Self::with_config(kind, n, descending, PlanConfig::default())
    }

    /// Compile the launch program for an artifact shape at an explicit
    /// [`PlanConfig`]. For `Sort` the program covers the full network;
    /// for `Merge` only the final merge phase (`log2(n)` steps — the
    /// paper §3 primitive, not a full re-sort).
    pub fn with_config(kind: ArtifactKind, n: usize, descending: bool, config: PlanConfig) -> Self {
        assert!(
            n.is_power_of_two(),
            "execution plans require a power-of-two row length, got {n}"
        );
        let (reverse_tail, launches) = if n < 2 {
            (false, Vec::new())
        } else {
            match kind {
                ArtifactKind::Sort => (false, Network::new(n).launches(config.variant, config.block)),
                // phase_len = n ⇒ every pair compares ascending
                // (i & n == 0 for all i < n).
                ArtifactKind::Merge => (
                    true,
                    Network::new(n).merge_launches(config.variant, config.block),
                ),
            }
        };
        Self {
            n,
            kind,
            reverse_tail,
            launches,
            reverse_output: descending,
            config,
            isa: config.kernel.resolve(),
        }
    }

    /// Row length the plan covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The artifact kind the program was compiled for.
    pub fn kind(&self) -> ArtifactKind {
        self.kind
    }

    /// The configuration the launch program was compiled at.
    pub fn config(&self) -> PlanConfig {
        self.config
    }

    /// The comparator ISA this plan executes with —
    /// [`PlanConfig::kernel`] resolved against this host at compile
    /// time.
    pub fn isa(&self) -> KernelIsa {
        self.isa
    }

    /// The compiled launch program, execution order — what the static
    /// network verifier expands and checks against the canonical
    /// schedule ([`crate::analysis::network_check`]).
    pub fn launches(&self) -> &[Launch] {
        &self.launches
    }

    /// Whether the plan reverses the row's second half before the
    /// launches (merge wiring).
    pub fn reverse_tail(&self) -> bool {
        self.reverse_tail
    }

    /// Whether the plan reverses the whole row after the launches
    /// (descending artifacts).
    pub fn reverse_output(&self) -> bool {
        self.reverse_output
    }

    /// Statically verify this plan without executing it: launch-program
    /// expansion vs the canonical schedule, then the 0–1 sorting proof.
    /// See [`crate::analysis::network_check::check_plan`].
    pub fn analyze(&self) -> crate::analysis::Report {
        let opts = crate::analysis::VerifyOptions::default();
        let mut cache = crate::analysis::network_check::ProofCache::new();
        let target = format!(
            "{} n={} {} block={} r={}",
            self.kind.name(),
            self.n,
            self.config.variant.name(),
            self.config.block,
            self.config.interleave,
        );
        crate::analysis::network_check::check_plan(self, &target, &opts, &mut cache)
    }

    /// Number of compare-exchange steps the plan covers per row (the
    /// network's step count — independent of fusion).
    pub fn step_count(&self) -> usize {
        self.launches.iter().map(Launch::step_count).sum()
    }

    /// Number of launches = full-row read+write passes over memory per
    /// row — the quantity the paper's two optimizations minimise (the
    /// pre/post reversal copies are excluded: they are identical across
    /// configurations of the same artifact). `Basic` pays one pass per
    /// step; `Semi`/`Optimized` strictly fewer once `n > block`.
    pub fn global_passes(&self) -> usize {
        self.launches.iter().map(Launch::global_passes).sum()
    }

    /// Execute the plan over one row of length [`Self::n`].
    pub fn run_row<T: SortKey>(&self, row: &mut [T]) {
        self.run_row_counting(row);
    }

    /// [`run_row`](Self::run_row), returning the number of full-row
    /// memory passes actually performed, measured inside the interpreter
    /// (elements streamed per launch — one tile per outer tile iteration
    /// for fused launches — divided by the row length; see
    /// [`crate::sort::network::run_launch_counting`]). This is the
    /// instrumented entry the pass-count tests and the ablation bench
    /// assert equals the static [`global_passes`](Self::global_passes):
    /// the two are computed independently, so an interpreter regression
    /// that re-streams the row (or skips part of it) breaks the equality.
    pub fn run_row_counting<T: SortKey>(&self, row: &mut [T]) -> usize {
        debug_assert_eq!(row.len(), self.n);
        if self.reverse_tail && self.n >= 2 {
            row[self.n / 2..].reverse();
        }
        let mut streamed = 0;
        for l in &self.launches {
            streamed += run_launch_counting_isa(row, l, self.isa);
        }
        if self.reverse_output {
            row.reverse();
        }
        if self.launches.is_empty() {
            0
        } else {
            debug_assert_eq!(streamed % self.n, 0);
            streamed / self.n
        }
    }

    /// Execute the plan over a row-major tile of `tile.len() / n` rows.
    ///
    /// With more than one row, this is the **batch-interleaved** path:
    /// the tile is transposed into an element-major scratch layout
    /// (`scratch[e * r + l]` = element `e` of row `l`), every launch runs
    /// across all rows at once via
    /// [`crate::sort::network::run_launch_interleaved`] — long branchless
    /// stride-1 sweeps, one SIMD lane per row — and the result is
    /// transposed back. A single-row tile takes the scalar
    /// [`run_row`](Self::run_row) walk (no transpose tax). The lane count
    /// comes from the tile length, so a ragged final tile (batch not a
    /// multiple of the interleave width) simply runs narrower.
    ///
    /// `scratch` is caller-provided so one allocation amortises across a
    /// batch's tiles; it is cleared and refilled here.
    pub fn run_tile<T: SortKey>(&self, tile: &mut [T], scratch: &mut Vec<T>) {
        let n = self.n;
        debug_assert!(n >= 1 && tile.len() % n == 0);
        let r = tile.len() / n;
        if r <= 1 || n < 2 {
            for row in tile.chunks_mut(n) {
                self.run_row(row);
            }
            return;
        }
        if self.reverse_tail {
            for row in tile.chunks_mut(n) {
                row[n / 2..].reverse();
            }
        }
        scratch.clear();
        scratch.reserve(r * n);
        for e in 0..n {
            for l in 0..r {
                scratch.push(tile[l * n + e]);
            }
        }
        for launch in &self.launches {
            run_launch_interleaved_isa(scratch, launch, r, self.isa);
        }
        for (l, row) in tile.chunks_mut(n).enumerate() {
            for (e, x) in row.iter_mut().enumerate() {
                *x = scratch[e * r + l];
            }
        }
        if self.reverse_output {
            for row in tile.chunks_mut(n) {
                row.reverse();
            }
        }
    }
}

/// The batch-interleave width a `(B, N)` batch actually executes at: the
/// configured R clamped to the batch — and, with `threads > 1` pool
/// workers, narrowed so the batch still splits into at least one tile
/// per worker (floor division: `r <= b/threads` guarantees
/// `ceil(b/r) >= threads` tiles even on ragged batches). Thread
/// parallelism scales near-linearly while lane parallelism tops out at a
/// small constant, so a (B=8, threads=8) batch must become 8 scalar row
/// jobs, not one 8-wide tile on the dispatching thread.
///
/// This is the **single definition** of the narrowing policy: the
/// dispatch ([`execute_batch`]), the autotuner's candidate reduction
/// (`runtime::autotune::tune`) and the bench trajectory's
/// `interleave_effective` label all call it, so the profile always
/// records widths that serving really executes.
pub fn effective_interleave(want: usize, b: usize, threads: usize) -> usize {
    let cap = if threads > 1 { b / threads } else { b };
    want.max(1).min(cap.max(1)).min(b.max(1))
}

/// The tile/job partition [`execute_batch`] dispatches for a `(B, N)`
/// batch — computed in one place so the dispatch loop and the static
/// tile-disjointness checker ([`crate::analysis::disjoint`]) can never
/// disagree about the geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DispatchGeometry {
    /// Effective interleave width (rows per tile; the final tile of a
    /// ragged batch runs narrower).
    pub r: usize,
    /// Elements per full tile (`r * n`).
    pub tile_len: usize,
    /// Whether the pool path engages (`threads > 1 && b > r && n >= 64`).
    pub pooled: bool,
    /// Elements per pool job (`tiles_per_job * tile_len`; the whole
    /// batch when unpooled). Jobs are consecutive `job_len` chunks of
    /// the row buffer, the last one ragged.
    pub job_len: usize,
}

/// Compute the dispatch geometry for a batch of `b` rows of length `n`
/// with configured interleave `want` on `threads` pool workers.
pub fn dispatch_geometry(want: usize, n: usize, b: usize, threads: usize) -> DispatchGeometry {
    let r = effective_interleave(want, b, threads);
    let n = n.max(1);
    let tile_len = r * n;
    let pooled = threads > 1 && b > r && n >= 64;
    let job_len = if pooled {
        let tiles = b.div_ceil(r);
        // Oversubscribe 2× so uneven worker speeds load-balance.
        let jobs = (threads * 2).min(tiles);
        tiles.div_ceil(jobs) * tile_len
    } else {
        (b * n).max(tile_len)
    };
    DispatchGeometry { r, tile_len, pooled, job_len }
}

/// Drive `plan` over a row-major `(B, N)` buffer, honouring the plan's
/// batch-interleave width and (when given) dispatching whole tiles onto
/// the shared pool — the one batch-execution path shared by
/// [`SortExecutor::execute`] and the autotuner's measurement loop, so the
/// numbers `bitonic-tpu tune` records are produced by exactly the code
/// the serving path runs.
pub(crate) fn execute_batch<T: SortKey>(
    plan: &ExecutionPlan,
    pool: Option<&ThreadPool>,
    rows: &mut [T],
) -> crate::Result<()> {
    let n = plan.n().max(1);
    debug_assert_eq!(rows.len() % n, 0);
    let b = rows.len() / n;
    let geo = dispatch_geometry(
        plan.config().interleave,
        n,
        b,
        pool.map_or(1, |p| p.threads()),
    );
    match pool {
        // Tile-parallel path: worth the dispatch only when several tiles
        // can overlap and each row carries real work. The job/tile
        // partition is row-aligned and covers the buffer exactly once —
        // proven statically over the geometry grid by
        // `analysis::disjoint::check_tile_dispatch` (the checker consumes
        // the same `dispatch_geometry` this dispatch does).
        Some(pool) if geo.pooled => {
            let tasks: Vec<ScopedJob> = rows
                .chunks_mut(geo.job_len)
                .map(|chunk| {
                    Box::new(move || {
                        let mut scratch = Vec::new();
                        for tile in chunk.chunks_mut(geo.tile_len) {
                            plan.run_tile(tile, &mut scratch);
                        }
                    }) as ScopedJob
                })
                .collect();
            pool.run_scoped(tasks).map_err(|panicked| {
                crate::err!("{panicked} sort task(s) panicked during parallel execute")
            })?;
        }
        _ => {
            let mut scratch = Vec::new();
            for tile in rows.chunks_mut(geo.tile_len) {
                plan.run_tile(tile, &mut scratch);
            }
        }
    }
    Ok(())
}

/// One loaded sort/merge artifact, ready to execute.
pub struct SortExecutor {
    /// The artifact this executor was built from.
    pub meta: ArtifactMeta,
    /// Size of the loaded HLO text in bytes (artifact was really read).
    pub hlo_bytes: usize,
    /// The precomputed schedule (plan layer).
    plan: ExecutionPlan,
    /// Shared row-parallel pool; `None` ⇒ serial execution.
    pool: Option<Arc<ThreadPool>>,
}

impl SortExecutor {
    /// Load and validate `hlo_text_path` for `meta`, serial execution at
    /// the default [`PlanConfig`]. The HLO text must exist, look like an
    /// HLO module, and declare the dtype + `(batch, n)` shape the
    /// manifest promises.
    pub fn compile(meta: ArtifactMeta, hlo_text_path: &Path) -> crate::Result<Self> {
        Self::compile_with_pool(meta, hlo_text_path, None, PlanConfig::default())
    }

    /// [`compile`](Self::compile) with a shared execution pool and an
    /// explicit plan configuration: rows of each `(B, N)` batch are
    /// sorted in parallel on `pool`, each walking the launch program
    /// compiled at `plan`.
    pub fn compile_with_pool(
        meta: ArtifactMeta,
        hlo_text_path: &Path,
        pool: Option<Arc<ThreadPool>>,
        plan: PlanConfig,
    ) -> crate::Result<Self> {
        crate::ensure!(
            meta.n.is_power_of_two() && meta.batch >= 1,
            "artifact {} has a malformed shape ({}x{})",
            meta.name,
            meta.batch,
            meta.n
        );
        // Reject a bad plan here, on the Result path: Network::launches
        // asserts the same thing, but that assert would fire inside the
        // device-host thread and kill it for every subsequent request.
        crate::ensure!(
            plan.block.is_power_of_two() && plan.block >= 2,
            "plan block must be a power of two >= 2, got {}",
            plan.block
        );
        crate::ensure!(
            plan.interleave >= 1,
            "plan interleave must be >= 1 (1 = scalar execution), got 0"
        );
        // Same rationale for the comparator ISA: `--kernel avx2` on a
        // host (or build) without AVX2 must fail the compile, not
        // silently degrade to scalar inside the device-host thread.
        plan.kernel
            .validate()
            .with_context(|| format!("compiling artifact {}", meta.name))?;
        let text = std::fs::read_to_string(hlo_text_path)
            .with_context(|| format!("reading {hlo_text_path:?} — generate artifacts with `python -m compile.aot` (see README)"))?;
        crate::ensure!(
            text.contains("HloModule"),
            "{hlo_text_path:?} does not look like HLO text"
        );
        // Validate the dtype token together with the shape (`u32[2,8]`,
        // not just `[2,8]`): a manifest dtype/file mismatch must fail at
        // load time, like a real PJRT compile would.
        let shape = format!("{}[{},{}]", meta.dtype.hlo_token(), meta.batch, meta.n);
        crate::ensure!(
            text.contains(&shape),
            "artifact {} HLO text does not declare {shape} — manifest dtype/shape vs file mismatch",
            meta.name
        );
        let plan = ExecutionPlan::with_config(meta.kind, meta.n, meta.descending, plan);
        Ok(Self {
            meta,
            hlo_bytes: text.len(),
            plan,
            pool,
        })
    }

    /// The precomputed schedule this executor walks.
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Worker threads available for row-parallel execution (1 ⇒ serial).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Sort a full `(batch, n)` buffer of u32 keys, row-major, in place.
    /// Returns the sorted rows in the same layout. This is the hot path:
    /// the buffer is taken by value (the host thread already owns it) so
    /// no defensive copy happens per batch.
    pub fn sort_u32(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        crate::ensure!(
            self.meta.dtype == Dtype::U32,
            "artifact {} holds {:?} keys",
            self.meta.name,
            self.meta.dtype
        );
        self.execute(rows)
    }

    /// Sort `(batch, n)` i32 keys.
    pub fn sort_i32(&self, rows: Vec<i32>) -> crate::Result<Vec<i32>> {
        crate::ensure!(self.meta.dtype == Dtype::I32, "dtype mismatch");
        self.execute(rows)
    }

    /// Sort `(batch, n)` f32 keys (finite values only — NaN ordering is
    /// not defined for the min/max network; see DESIGN.md §6).
    pub fn sort_f32(&self, rows: Vec<f32>) -> crate::Result<Vec<f32>> {
        crate::ensure!(self.meta.dtype == Dtype::F32, "dtype mismatch");
        self.execute(rows)
    }

    fn execute<T: SortKey>(&self, mut rows: Vec<T>) -> crate::Result<Vec<T>> {
        let (b, n) = (self.meta.batch, self.meta.n);
        crate::ensure!(
            rows.len() == b * n,
            "artifact {} wants {}x{} ({} bytes), got {} bytes",
            self.meta.name,
            b,
            n,
            b * n * self.meta.dtype.size(),
            rows.len() * self.meta.dtype.size()
        );
        execute_batch(&self.plan, self.pool.as_deref(), &mut rows)
            .map_err(|e| e.context(format!("artifact {}", self.meta.name)))?;
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::network::Variant;
    use crate::util::prop::{check_with, Config, Strategy};
    use crate::workload::rng::Pcg32;
    use crate::workload::{Distribution, Generator};

    fn meta(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> ArtifactMeta {
        ArtifactMeta {
            name: "test".into(),
            kind,
            variant: Variant::Optimized,
            batch,
            n,
            dtype,
            descending: desc,
            block: 256,
            grid_cells: 4,
            file: "test.hlo.txt".into(),
        }
    }

    fn executor_with_pool(
        kind: ArtifactKind,
        batch: usize,
        n: usize,
        dtype: Dtype,
        desc: bool,
        pool: Option<Arc<ThreadPool>>,
    ) -> SortExecutor {
        SortExecutor {
            meta: meta(kind, batch, n, dtype, desc),
            hlo_bytes: 0,
            plan: ExecutionPlan::new(kind, n, desc),
            pool,
        }
    }

    fn executor(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> SortExecutor {
        executor_with_pool(kind, batch, n, dtype, desc, None)
    }

    #[test]
    fn effective_interleave_prefers_threads_over_lanes() {
        // Serial keeps the full width (clamped to the batch).
        assert_eq!(effective_interleave(8, 8, 1), 8);
        assert_eq!(effective_interleave(8, 3, 1), 3);
        assert_eq!(effective_interleave(0, 5, 1), 1, "0 treated as scalar");
        // With a pool, the batch must yield >= one tile per worker.
        assert_eq!(effective_interleave(8, 8, 8), 1);
        assert_eq!(effective_interleave(8, 16, 8), 2);
        assert_eq!(effective_interleave(8, 64, 4), 8);
        assert_eq!(effective_interleave(3, 5, 4), 1, "ragged: floor, not ceil");
        for b in 1..=64usize {
            for want in [1usize, 3, 4, 8, 16] {
                for threads in [2usize, 4, 8] {
                    let r = effective_interleave(want, b, threads);
                    assert!(r >= 1 && r <= b.max(1));
                    if b > r {
                        // Pool dispatch engages: enough tiles for everyone.
                        let tiles = b.div_ceil(r);
                        assert!(tiles >= threads.min(b), "b={b} want={want} t={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn merge_plan_merges_sorted_halves() {
        let mut gen = Generator::new(2);
        for logn in 1..=12 {
            let n = 1usize << logn;
            let plan = ExecutionPlan::new(ArtifactKind::Merge, n, false);
            let mut v = gen.u32s(n, Distribution::Uniform);
            v[..n / 2].sort_unstable();
            v[n / 2..].sort_unstable();
            let mut want = v.clone();
            want.sort_unstable();
            plan.run_row(&mut v);
            assert_eq!(v, want, "n=2^{logn}");
        }
    }

    #[test]
    fn plan_precomputes_full_network_for_sort() {
        let plan = ExecutionPlan::new(ArtifactKind::Sort, 1 << 10, false);
        assert_eq!(plan.step_count(), Network::new(1 << 10).step_count());
        assert_eq!(plan.n(), 1 << 10);
        assert_eq!(plan.config(), PlanConfig::default());
        // Merge plans walk only the final phase: log2(n) steps.
        let merge = ExecutionPlan::new(ArtifactKind::Merge, 1 << 10, false);
        assert_eq!(merge.step_count(), 10);
    }

    #[test]
    fn optimized_plan_slashes_global_passes() {
        // Acceptance: at the default block, Optimized performs strictly
        // fewer full-row memory passes than Semi, which performs strictly
        // fewer than the serial step walk (Basic = one pass per step) —
        // confirmed both statically and by a pass-counting instrumented
        // run. At (n=64K, block=4096) the counts are pinned exactly:
        // 136 → 15 → 11, the numbers ROADMAP records.
        let at = |variant, n| {
            ExecutionPlan::with_config(
                ArtifactKind::Sort,
                n,
                false,
                PlanConfig {
                    variant,
                    block: DEFAULT_PLAN_BLOCK,
                    interleave: 1,
                    ..Default::default()
                },
            )
        };
        for logn in [14usize, 16] {
            let n = 1 << logn;
            let (basic, semi, opt) =
                (at(Variant::Basic, n), at(Variant::Semi, n), at(Variant::Optimized, n));
            assert_eq!(basic.global_passes(), Network::new(n).step_count());
            assert!(
                opt.global_passes() < semi.global_passes()
                    && semi.global_passes() < basic.global_passes(),
                "passes must strictly drop: basic {} semi {} opt {} (n=2^{logn})",
                basic.global_passes(),
                semi.global_passes(),
                opt.global_passes()
            );
            // The instrumented run must execute exactly the static count,
            // and still sort.
            let mut gen = Generator::new(logn as u64);
            let mut row = gen.u32s(n, Distribution::Uniform);
            let executed = opt.run_row_counting(&mut row);
            assert_eq!(executed, opt.global_passes());
            assert!(crate::sort::is_sorted(&row));
        }
        let n = 1 << 16;
        assert_eq!(at(Variant::Basic, n).global_passes(), 136);
        assert_eq!(at(Variant::Semi, n).global_passes(), 15);
        assert_eq!(at(Variant::Optimized, n).global_passes(), 11);
    }

    /// Satellite: fused plans must be bit-exact with the serial step-walk
    /// plan (`Variant::Basic`) across u32/i32/f32 × sort/merge ×
    /// ascending/descending × block ∈ {4, 64, 1024}, including rows with
    /// a MAX-padded tail (the coordinator router's padding contract).
    #[test]
    fn fused_plans_bit_exact_with_step_walk_all_configs() {
        fn check<T>(rows_of: &mut dyn FnMut(usize) -> Vec<T>, label: &str)
        where
            T: SortKey + PartialEq + std::fmt::Debug,
        {
            let batch = 3usize;
            for kind in [ArtifactKind::Sort, ArtifactKind::Merge] {
                for descending in [false, true] {
                    for n in [64usize, 1024] {
                        for pad in [false, true] {
                            let mut rows = rows_of(batch * n);
                            for row in rows.chunks_mut(n) {
                                if pad {
                                    for x in &mut row[n - n / 3..] {
                                        *x = T::MAX_KEY;
                                    }
                                }
                                if kind == ArtifactKind::Merge {
                                    // Merge contract: halves sorted asc.
                                    let half = n / 2;
                                    crate::sort::bitonic::bitonic_sort(&mut row[..half]);
                                    crate::sort::bitonic::bitonic_sort(&mut row[half..]);
                                }
                            }
                            let walk = ExecutionPlan::with_config(
                                kind,
                                n,
                                descending,
                                PlanConfig {
                                    variant: Variant::Basic,
                                    block: DEFAULT_PLAN_BLOCK,
                                    interleave: 1,
                                    ..Default::default()
                                },
                            );
                            let mut want = rows.clone();
                            for row in want.chunks_mut(n) {
                                walk.run_row(row);
                            }
                            for variant in [Variant::Semi, Variant::Optimized] {
                                for block in [4usize, 64, 1024] {
                                    let plan = ExecutionPlan::with_config(
                                        kind,
                                        n,
                                        descending,
                                        PlanConfig {
                                            variant,
                                            block,
                                            interleave: 1,
                                            ..Default::default()
                                        },
                                    );
                                    let mut got = rows.clone();
                                    for row in got.chunks_mut(n) {
                                        plan.run_row(row);
                                    }
                                    assert_eq!(
                                        got, want,
                                        "{label} {kind:?} desc={descending} n={n} pad={pad} \
                                         {variant:?} block={block}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
        let mut g1 = Generator::new(0xFE11);
        check(&mut |c| g1.u32s(c, Distribution::DupHeavy), "u32");
        let mut g2 = Generator::new(0xFE12);
        check(
            &mut |c| {
                g2.u32s(c, Distribution::Uniform)
                    .into_iter()
                    .map(|x| x as i32)
                    .collect()
            },
            "i32",
        );
        let mut g3 = Generator::new(0xFE13);
        check(&mut |c| g3.f32s(c, Distribution::Uniform), "f32");
    }

    /// Satellite: batch-interleaved tiles must be bit-exact with the
    /// scalar row-at-a-time walk across u32/i32/f32 × sort/merge ×
    /// ascending/descending × R ∈ {1, 4, 16}, including MAX-padded rows
    /// and a ragged final tile (batch 5 is not a multiple of 4 or 16).
    #[test]
    fn interleaved_tiles_bit_exact_with_scalar_rows_all_configs() {
        fn check<T>(rows_of: &mut dyn FnMut(usize) -> Vec<T>, label: &str)
        where
            T: SortKey + PartialEq + std::fmt::Debug,
        {
            let batch = 5usize;
            let n = 256usize;
            for kind in [ArtifactKind::Sort, ArtifactKind::Merge] {
                for descending in [false, true] {
                    for pad in [false, true] {
                        let mut rows = rows_of(batch * n);
                        for row in rows.chunks_mut(n) {
                            if pad {
                                for x in &mut row[n - n / 3..] {
                                    *x = T::MAX_KEY;
                                }
                            }
                            if kind == ArtifactKind::Merge {
                                let half = n / 2;
                                crate::sort::bitonic::bitonic_sort(&mut row[..half]);
                                crate::sort::bitonic::bitonic_sort(&mut row[half..]);
                            }
                        }
                        let plan = |interleave| {
                            ExecutionPlan::with_config(
                                kind,
                                n,
                                descending,
                                PlanConfig {
                                    variant: Variant::Optimized,
                                    block: 64,
                                    interleave,
                                    ..Default::default()
                                },
                            )
                        };
                        let mut want = rows.clone();
                        for row in want.chunks_mut(n) {
                            plan(1).run_row(row);
                        }
                        for r in [1usize, 4, 16] {
                            let p = plan(r);
                            let mut got = rows.clone();
                            let mut scratch = Vec::new();
                            // Tile exactly as execute_batch does: R rows
                            // per tile, ragged tail allowed.
                            for tile in got.chunks_mut(r.min(batch) * n) {
                                p.run_tile(tile, &mut scratch);
                            }
                            assert_eq!(
                                got, want,
                                "{label} {kind:?} desc={descending} pad={pad} R={r}"
                            );
                        }
                    }
                }
            }
        }
        let mut g1 = Generator::new(0x11EA);
        check(&mut |c| g1.u32s(c, Distribution::DupHeavy), "u32");
        let mut g2 = Generator::new(0x11EB);
        check(
            &mut |c| {
                g2.u32s(c, Distribution::Uniform)
                    .into_iter()
                    .map(|x| x as i32)
                    .collect()
            },
            "i32",
        );
        let mut g3 = Generator::new(0x11EC);
        check(&mut |c| g3.f32s(c, Distribution::Uniform), "f32");
    }

    /// Same property one level up, through SortExecutor::execute with the
    /// pool dispatching whole interleaved tiles: scalar serial executor
    /// == interleaved pooled executor, for every interleave width.
    #[test]
    fn interleaved_executor_bit_exact_with_scalar_executor_pooled() {
        let pool = Arc::new(ThreadPool::new(4, 16));
        let (b, n) = (13usize, 512usize); // 13 rows: ragged tiles at R=4/16
        let mk = |interleave, pool: Option<Arc<ThreadPool>>| SortExecutor {
            meta: meta(ArtifactKind::Sort, b, n, Dtype::U32, false),
            hlo_bytes: 0,
            plan: ExecutionPlan::with_config(
                ArtifactKind::Sort,
                n,
                false,
                PlanConfig {
                    variant: Variant::Optimized,
                    block: 256,
                    interleave,
                    ..Default::default()
                },
            ),
            pool,
        };
        let mut gen = Generator::new(0xAB51);
        let rows = gen.u32s(b * n, Distribution::DupHeavy);
        let want = mk(1, None).sort_u32(rows.clone()).unwrap();
        for r in [1usize, 4, 8, 16] {
            let got = mk(r, Some(Arc::clone(&pool))).sort_u32(rows.clone()).unwrap();
            assert_eq!(got, want, "R={r} pooled");
            let got_serial = mk(r, None).sort_u32(rows.clone()).unwrap();
            assert_eq!(got_serial, want, "R={r} serial");
        }
    }

    #[test]
    fn fused_executor_bit_exact_with_step_walk_executor_pooled() {
        // Same property one level up: through SortExecutor::execute with
        // the row-chunk pool dispatch in the loop.
        let pool = Arc::new(ThreadPool::new(4, 16));
        let (b, n) = (8usize, 512usize);
        let mk = |variant, block, pool: Option<Arc<ThreadPool>>| SortExecutor {
            meta: meta(ArtifactKind::Sort, b, n, Dtype::U32, false),
            hlo_bytes: 0,
            plan: ExecutionPlan::with_config(
                ArtifactKind::Sort,
                n,
                false,
                PlanConfig { variant, block, interleave: 1, ..Default::default() },
            ),
            pool,
        };
        let mut gen = Generator::new(0xAB5);
        let rows = gen.u32s(b * n, Distribution::DupHeavy);
        let want = mk(Variant::Basic, 64, None).sort_u32(rows.clone()).unwrap();
        for variant in [Variant::Semi, Variant::Optimized] {
            for block in [4usize, 64, 1024] {
                let got = mk(variant, block, Some(Arc::clone(&pool)))
                    .sort_u32(rows.clone())
                    .unwrap();
                assert_eq!(got, want, "{variant:?} block={block}");
            }
        }
    }

    #[test]
    fn executes_batch_rows_independently() {
        let exe = executor(ArtifactKind::Sort, 3, 8, Dtype::U32, false);
        let rows = vec![
            7, 6, 5, 4, 3, 2, 1, 0, // row 0
            0, 2, 1, 3, 5, 4, 7, 6, // row 1
            9, 9, 9, 9, 0, 0, 0, 0, // row 2
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[16..24], &[0, 0, 0, 0, 9, 9, 9, 9]);
    }

    #[test]
    fn descending_reverses_rows() {
        let exe = executor(ArtifactKind::Sort, 1, 8, Dtype::U32, true);
        let out = exe.sort_u32(vec![3, 1, 4, 1, 5, 9, 2, 6]).unwrap();
        assert_eq!(out, vec![9, 6, 5, 4, 3, 2, 1, 1]);
    }

    #[test]
    fn wrong_size_mentions_bytes() {
        let exe = executor(ArtifactKind::Sort, 2, 8, Dtype::U32, false);
        let err = exe.sort_u32(vec![1, 2, 3]).unwrap_err();
        assert!(format!("{err:#}").contains("bytes"), "{err:#}");
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let exe = executor(ArtifactKind::Sort, 1, 4, Dtype::F32, false);
        assert!(exe.sort_u32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_i32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_f32(vec![1.0, 0.5, 2.0, -1.0]).is_ok());
    }

    #[test]
    fn compile_validates_hlo_text() {
        let dir = std::env::temp_dir().join("bitonic-tpu-executor-tests");
        std::fs::create_dir_all(&dir).unwrap();

        // Missing file errors with the regeneration hint.
        let missing = SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &dir.join("nope.hlo.txt"),
        );
        assert!(format!("{:#}", missing.unwrap_err()).contains("compile.aot"));

        // Garbage content rejected.
        let garbage = dir.join("garbage.hlo.txt");
        std::fs::write(&garbage, "not hlo at all").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &garbage
        )
        .is_err());

        // Shape mismatch rejected; matching dtype+shape accepted.
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule test\nENTRY main { u32[2,8] parameter(0) }\n").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 4, 8, Dtype::U32, false),
            &good
        )
        .is_err());
        // Dtype mismatch at the same shape also rejected: the manifest
        // claims f32 but the HLO declares u32[2,8].
        let dtype_drift = SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::F32, false),
            &good,
        );
        assert!(
            format!("{:#}", dtype_drift.unwrap_err()).contains("f32[2,8]"),
            "dtype drift must name the expected token"
        );
        let exe =
            SortExecutor::compile(meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false), &good)
                .unwrap();
        assert!(exe.hlo_bytes > 0);
        assert_eq!(exe.threads(), 1);
        assert_eq!(exe.plan().step_count(), Network::new(8).step_count());

        // A malformed plan block errors on the Result path instead of
        // panicking inside the device-host thread later.
        let bad_plan = SortExecutor::compile_with_pool(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &good,
            None,
            PlanConfig { block: 3, interleave: 1, ..Default::default() },
        );
        assert!(format!("{:#}", bad_plan.unwrap_err()).contains("power of two"));

        // interleave = 0 is rejected on the same Result path.
        let bad_interleave = SortExecutor::compile_with_pool(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &good,
            None,
            PlanConfig { block: 4, interleave: 0, ..Default::default() },
        );
        assert!(format!("{:#}", bad_interleave.unwrap_err()).contains("interleave"));

        // A fixed comparator ISA this host can't execute is rejected on
        // the same Result path (`Auto` never errors — it resolves to a
        // supported ISA). Every available ISA compiles and is the one
        // the plan reports.
        if !KernelIsa::Avx2.available() {
            let bad_kernel = SortExecutor::compile_with_pool(
                meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
                &good,
                None,
                PlanConfig {
                    kernel: KernelChoice::Fixed(KernelIsa::Avx2),
                    ..Default::default()
                },
            );
            assert!(format!("{:#}", bad_kernel.unwrap_err()).contains("not available"));
        }
        for isa in KernelIsa::available_isas() {
            let exe = SortExecutor::compile_with_pool(
                meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
                &good,
                None,
                PlanConfig { kernel: KernelChoice::Fixed(isa), ..Default::default() },
            )
            .unwrap();
            assert_eq!(exe.plan().isa(), isa, "fixed {} must stay pinned", isa.name());
        }
    }

    #[test]
    fn merge_artifact_end_to_end() {
        let exe = executor(ArtifactKind::Merge, 2, 8, Dtype::U32, false);
        let rows = vec![
            1, 3, 5, 7, 0, 2, 4, 6, // two sorted halves
            0, 0, 1, 1, 0, 1, 2, 3, // duplicates across halves
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 0, 0, 1, 1, 1, 2, 3]);
    }

    #[test]
    fn pooled_execution_sorts_large_batches() {
        let pool = Arc::new(ThreadPool::new(4, 16));
        let exe = executor_with_pool(ArtifactKind::Sort, 16, 256, Dtype::U32, false, Some(pool));
        assert_eq!(exe.threads(), 4);
        let mut gen = Generator::new(0xB00);
        let rows = gen.u32s(16 * 256, Distribution::Uniform);
        let out = exe.sort_u32(rows.clone()).unwrap();
        for r in 0..16 {
            let mut want = rows[r * 256..(r + 1) * 256].to_vec();
            want.sort_unstable();
            assert_eq!(&out[r * 256..(r + 1) * 256], &want[..], "row {r}");
        }
    }

    /// One random executor configuration for the bit-exactness property.
    #[derive(Clone, Debug)]
    struct Case {
        kind: ArtifactKind,
        dtype: Dtype,
        descending: bool,
        batch: usize,
        n: usize,
        seed: u64,
    }

    struct CaseStrategy;
    impl Strategy for CaseStrategy {
        type Value = Case;
        fn sample(&self, rng: &mut Pcg32) -> Case {
            Case {
                kind: if rng.next_below(2) == 0 {
                    ArtifactKind::Sort
                } else {
                    ArtifactKind::Merge
                },
                dtype: match rng.next_below(3) {
                    0 => Dtype::U32,
                    1 => Dtype::I32,
                    _ => Dtype::F32,
                },
                descending: rng.next_below(2) == 1,
                batch: 1 + rng.next_below(8) as usize,
                n: 1usize << (1 + rng.next_below(8)), // 2..=256
                seed: rng.next_u32() as u64,
            }
        }
        fn shrink(&self, v: &Case) -> Vec<Case> {
            let mut out = Vec::new();
            if v.batch > 1 {
                out.push(Case { batch: v.batch / 2, ..v.clone() });
            }
            if v.n > 2 {
                out.push(Case { n: v.n / 2, ..v.clone() });
            }
            out
        }
    }

    /// Run the same input through a serial and a pooled executor of the
    /// same configuration; outputs must agree bit-for-bit. An odd
    /// interleave width (3) keeps the tile count above the batch-clamped
    /// width, so the pooled executor really exercises the tile-dispatch
    /// path (and non-power-of-two lane counts) whenever `batch > 3`.
    fn assert_bit_exact<T>(case: &Case, pool: &Arc<ThreadPool>, mut rows: Vec<T>) -> Result<(), String>
    where
        T: SortKey + PartialEq + std::fmt::Debug,
    {
        if case.kind == ArtifactKind::Merge {
            // Merge contract: each row's two halves arrive sorted asc.
            for row in rows.chunks_mut(case.n) {
                let half = case.n / 2;
                crate::sort::bitonic::bitonic_sort(&mut row[..half]);
                crate::sort::bitonic::bitonic_sort(&mut row[half..]);
            }
        }
        let config = PlanConfig {
            interleave: 3,
            ..PlanConfig::default()
        };
        let mk = |pool: Option<Arc<ThreadPool>>| SortExecutor {
            meta: meta(case.kind, case.batch, case.n, case.dtype, case.descending),
            hlo_bytes: 0,
            plan: ExecutionPlan::with_config(case.kind, case.n, case.descending, config),
            pool,
        };
        let serial = mk(None);
        let pooled = mk(Some(Arc::clone(pool)));
        let a = serial.execute(rows.clone()).map_err(|e| format!("{e:#}"))?;
        let b = pooled.execute(rows).map_err(|e| format!("{e:#}"))?;
        if a != b {
            return Err("parallel output diverged from serial".into());
        }
        Ok(())
    }

    #[test]
    fn pooled_bit_exact_with_serial_across_dtypes_kinds_directions() {
        let pool = Arc::new(ThreadPool::new(4, 32));
        check_with(
            Config {
                cases: 48,
                ..Config::default()
            },
            &CaseStrategy,
            |case| {
                let mut gen = Generator::new(case.seed);
                let count = case.batch * case.n;
                match case.dtype {
                    Dtype::U32 => {
                        assert_bit_exact(case, &pool, gen.u32s(count, Distribution::DupHeavy))
                    }
                    Dtype::I32 => {
                        let rows: Vec<i32> = gen
                            .u32s(count, Distribution::Uniform)
                            .into_iter()
                            .map(|x| x as i32)
                            .collect();
                        assert_bit_exact(case, &pool, rows)
                    }
                    Dtype::F32 => {
                        // Finite floats only (generator contract).
                        assert_bit_exact(case, &pool, gen.f32s(count, Distribution::Uniform))
                    }
                }
            },
        );
    }
}
