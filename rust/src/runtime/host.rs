//! Device-host thread: the single owner of the execution backend.
//!
//! In the PJRT design the client/executable wrappers are `!Send`/`!Sync`
//! (`Rc` + raw PJRT pointers), so the runtime follows the
//! single-device-owner model: one OS thread owns the [`Registry`] and
//! serves execution requests over a channel. The native-CPU executor has
//! no such constraint, but the model is kept — it matches the hardware
//! reality a real accelerator imposes (one device, executions serialise),
//! and it keeps the swap back to PJRT local to the executor. Handles are
//! cheap to clone and freely shared across the coordinator's workers.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use crate::util::error::Context;
use crate::util::threadpool::ThreadPool;

use super::artifact::Manifest;
use super::autotune::PlanPolicy;
use super::registry::{Key, Registry};
use crate::sort::network::Variant;

/// Device-host configuration.
#[derive(Clone, Debug)]
pub struct HostConfig {
    /// Row-parallel executor threads: `> 1` gives the host a shared
    /// [`ThreadPool`] and every executor sorts its `(B, N)` rows in
    /// parallel on it; `0` or `1` keeps execution serial.
    pub threads: usize,
    /// How every executor's launch program is configured (fusion variant,
    /// fused-tile block, batch-interleave width): a base
    /// [`super::PlanConfig`] — which converts into a fixed policy via
    /// `.into()` — optionally refined per size class by a tuning profile
    /// (`bitonic-tpu tune`). CLI: `--plan-variant` / `--plan-block` /
    /// `--plan-interleave` / `--profile` / `--no-profile`.
    pub plan: PlanPolicy,
}

impl Default for HostConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            plan: PlanPolicy::default(),
        }
    }
}

enum Request {
    SortU32 {
        key: Key,
        rows: Vec<u32>,
        reply: Sender<crate::Result<Vec<u32>>>,
    },
    SortI32 {
        key: Key,
        rows: Vec<i32>,
        reply: Sender<crate::Result<Vec<i32>>>,
    },
    SortF32 {
        key: Key,
        rows: Vec<f32>,
        reply: Sender<crate::Result<Vec<f32>>>,
    },
    WarmUp {
        variant: Variant,
        reply: Sender<crate::Result<usize>>,
    },
    CompiledCount {
        reply: Sender<usize>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the device-host thread.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<Request>,
}

macro_rules! roundtrip {
    ($self:ident, $variant:ident { $($field:ident : $value:expr),* $(,)? }) => {{
        let (reply, rx) = channel();
        $self
            .tx
            .send(Request::$variant { $($field: $value,)* reply })
            .map_err(|_| crate::err!("device host is gone"))?;
        rx.recv().map_err(|_| crate::err!("device host dropped reply"))?
    }};
}

impl DeviceHandle {
    /// Sort a `(batch, n)` u32 buffer with the artifact `key`.
    pub fn sort_u32(&self, key: Key, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        roundtrip!(self, SortU32 { key: key, rows: rows })
    }

    /// Sort a `(batch, n)` i32 buffer.
    pub fn sort_i32(&self, key: Key, rows: Vec<i32>) -> crate::Result<Vec<i32>> {
        roundtrip!(self, SortI32 { key: key, rows: rows })
    }

    /// Sort a `(batch, n)` f32 buffer (finite keys).
    pub fn sort_f32(&self, key: Key, rows: Vec<f32>) -> crate::Result<Vec<f32>> {
        roundtrip!(self, SortF32 { key: key, rows: rows })
    }

    /// Compile every artifact of `variant` ahead of traffic.
    pub fn warm_up(&self, variant: Variant) -> crate::Result<usize> {
        roundtrip!(self, WarmUp { variant: variant })
    }

    /// Number of compiled executables cached on the host.
    pub fn compiled_count(&self) -> crate::Result<usize> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::CompiledCount { reply })
            .map_err(|_| crate::err!("device host is gone"))?;
        rx.recv().context("device host dropped reply")
    }

    /// Ask the host thread to exit once queued work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// Spawn the device-host thread over the artifacts in `dir` with serial
/// executors (see [`spawn_with`] for the row-parallel configuration).
///
/// Returns the handle plus a *snapshot* of the manifest (plain data, so
/// callers can route/plan without round-tripping to the host).
pub fn spawn(dir: impl AsRef<std::path::Path>) -> crate::Result<(DeviceHandle, Manifest)> {
    spawn_with(dir, HostConfig::default())
}

/// [`spawn`] with explicit configuration: `config.threads > 1` builds a
/// shared [`ThreadPool`] owned by the host thread, and every executor
/// the registry loads partitions its `(B, N)` buffer into row-chunk
/// tasks on it — the host thread stops being the serial bottleneck while
/// the single-device-owner model (one batch in flight) is preserved.
pub fn spawn_with(
    dir: impl AsRef<std::path::Path>,
    config: HostConfig,
) -> crate::Result<(DeviceHandle, Manifest)> {
    // Parse the manifest on the caller thread first: fail fast, and give
    // the caller its snapshot without a channel round-trip.
    spawn_manifest(Manifest::load(dir)?, config)
}

/// [`spawn_with`] plus merged artifact discovery: the menu is the union
/// of `dir`'s manifest and the generated-artifacts dir resolved by
/// [`super::generated_artifacts_dir`] (`$BITONIC_GEN_ARTIFACTS`, else
/// `<dir>/generated`) when one exists — fixture rows win on collisions.
/// This is what the CLI drivers use, so a `bitonic-tpu gen-artifacts`
/// run extends every subsequent sort/serve/bench menu without flags.
pub fn spawn_discovered(
    dir: impl AsRef<std::path::Path>,
    config: HostConfig,
) -> crate::Result<(DeviceHandle, Manifest)> {
    let dir = dir.as_ref();
    let manifest = match super::generated_artifacts_dir(dir) {
        Some(generated) => Manifest::load_merged(dir, &generated)?,
        None => Manifest::load(dir)?,
    };
    spawn_manifest(manifest, config)
}

/// Spawn the host thread over an already-loaded manifest snapshot; the
/// registry the host serves is built from the same snapshot, so caller
/// and host can never disagree about the menu.
pub fn spawn_manifest(
    manifest: Manifest,
    config: HostConfig,
) -> crate::Result<(DeviceHandle, Manifest)> {
    let host_manifest = manifest.clone();
    let (tx, rx) = channel::<Request>();
    let (ready_tx, ready_rx) = channel::<crate::Result<()>>();
    std::thread::Builder::new()
        .name("pjrt-device-host".into())
        .spawn(move || {
            let pool = (config.threads > 1)
                .then(|| Arc::new(ThreadPool::new(config.threads, 2 * config.threads)));
            let registry = Registry::from_manifest(host_manifest, pool, config.plan);
            let _ = ready_tx.send(Ok(()));
            while let Ok(req) = rx.recv() {
                match req {
                    Request::SortU32 { key, rows, reply } => {
                        let res = registry.get(key).and_then(|exe| exe.sort_u32(rows));
                        let _ = reply.send(res);
                    }
                    Request::SortI32 { key, rows, reply } => {
                        let res = registry.get(key).and_then(|exe| exe.sort_i32(rows));
                        let _ = reply.send(res);
                    }
                    Request::SortF32 { key, rows, reply } => {
                        let res = registry.get(key).and_then(|exe| exe.sort_f32(rows));
                        let _ = reply.send(res);
                    }
                    Request::WarmUp { variant, reply } => {
                        let _ = reply.send(registry.warm_up(variant));
                    }
                    Request::CompiledCount { reply } => {
                        let _ = reply.send(registry.compiled_count());
                    }
                    Request::Shutdown => break,
                }
            }
        })
        .context("spawning device host")?;
    ready_rx
        .recv()
        .context("device host died during init")??;
    Ok((DeviceHandle { tx }, manifest))
}
