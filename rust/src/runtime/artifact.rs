//! Artifact manifest parsing.
//!
//! `artifacts/manifest.tsv` is written by `python/compile/aot.py`; columns:
//! `name variant batch n dtype descending block grid_cells file`.

use std::path::{Path, PathBuf};

use crate::util::error::Context;

use crate::sort::network::Variant;

/// Key dtype of an artifact (matches the jnp dtype string).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 32-bit unsigned (the paper's workload).
    U32,
    /// 32-bit signed.
    I32,
    /// 32-bit float (paper §6 future work).
    F32,
}

impl Dtype {
    /// Parse the jnp dtype name used in the manifest.
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "uint32" => Dtype::U32,
            "int32" => Dtype::I32,
            "float32" => Dtype::F32,
            other => crate::bail!("unsupported dtype in manifest: {other}"),
        })
    }

    /// The manifest/jnp name.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::U32 => "uint32",
            Dtype::I32 => "int32",
            Dtype::F32 => "float32",
        }
    }

    /// Bytes per key.
    pub fn size(self) -> usize {
        4
    }

    /// The dtype token XLA prints in HLO shapes (`u32[8,1024]` etc.);
    /// note int32 is spelled `s32` there, not `i32`.
    pub fn hlo_token(self) -> &'static str {
        match self {
            Dtype::U32 => "u32",
            Dtype::I32 => "s32",
            Dtype::F32 => "f32",
        }
    }
}

/// What computation an artifact performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Full bitonic sort of each row.
    Sort,
    /// Bitonic merge of rows whose two halves are each sorted (paper §3's
    /// primitive; log-depth — used by `sort::hybrid`).
    Merge,
}

impl ArtifactKind {
    /// Parse the manifest name.
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "sort" => ArtifactKind::Sort,
            "merge" => ArtifactKind::Merge,
            other => crate::bail!("unknown artifact kind {other:?}"),
        })
    }

    /// The manifest name (inverse of [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Sort => "sort",
            ArtifactKind::Merge => "merge",
        }
    }
}

/// Metadata for one compiled-sort artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Unique artifact name (also the filename stem).
    pub name: String,
    /// Sort or merge.
    pub kind: ArtifactKind,
    /// Which launch-schedule variant the artifact implements.
    pub variant: Variant,
    /// Batch dimension B of the (B, N) input.
    pub batch: usize,
    /// Row length N (power of two).
    pub n: usize,
    /// Key dtype.
    pub dtype: Dtype,
    /// True if the artifact sorts descending.
    pub descending: bool,
    /// VMEM tile width the fused stages used.
    pub block: usize,
    /// Interpret-mode grid split the kernels used.
    pub grid_cells: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
}

/// Parsed manifest: all artifacts plus the directory they live in.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory containing manifest.tsv and the .hlo.txt files.
    pub dir: PathBuf,
    /// All artifact entries, manifest order.
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — generate artifacts with `python -m compile.aot` (see README)"))?;
        Self::parse(dir, &text)
    }

    /// Load `<primary>/manifest.tsv` and merge `<extra>/manifest.tsv`
    /// on top of it: the union of both menus, with *primary* rows
    /// winning on size-class collisions (so a generated grid can never
    /// shadow the audited fixture). Merged-in entries carry an
    /// *absolute* `file` path — [`Manifest::path_of`] joins against
    /// `self.dir`, and joining an absolute path is the identity, so
    /// every existing consumer resolves both dirs unchanged.
    pub fn load_merged(
        primary: impl AsRef<Path>,
        extra: impl AsRef<Path>,
    ) -> crate::Result<Self> {
        let mut base = Self::load(primary)?;
        let extra_dir = extra.as_ref();
        let added = Self::load(extra_dir)
            .with_context(|| format!("merging generated artifacts from {extra_dir:?}"))?;
        let taken: std::collections::HashSet<crate::runtime::registry::Key> =
            base.entries.iter().map(crate::runtime::registry::Key::of).collect();
        for mut meta in added.entries {
            if taken.contains(&crate::runtime::registry::Key::of(&meta)) {
                continue;
            }
            meta.file = added.dir.join(&meta.file);
            base.entries.push(meta);
        }
        Ok(base)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: PathBuf, text: &str) -> crate::Result<Self> {
        let mut lines = text.lines();
        let header: Vec<&str> = lines
            .next()
            .context("empty manifest")?
            .split('\t')
            .collect();
        let idx = |col: &str| -> crate::Result<usize> {
            header
                .iter()
                .position(|h| *h == col)
                .with_context(|| format!("manifest missing column {col:?}"))
        };
        let (c_name, c_kind, c_variant, c_batch, c_n, c_dtype, c_desc, c_block, c_cells, c_file) = (
            idx("name")?,
            idx("kind")?,
            idx("variant")?,
            idx("batch")?,
            idx("n")?,
            idx("dtype")?,
            idx("descending")?,
            idx("block")?,
            idx("grid_cells")?,
            idx("file")?,
        );
        let mut entries = Vec::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            let get = |i: usize| -> crate::Result<&str> {
                f.get(i)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing field {i}", lineno + 2))
            };
            let variant = Variant::parse(get(c_variant)?)
                .with_context(|| format!("bad variant on line {}", lineno + 2))?;
            entries.push(ArtifactMeta {
                name: get(c_name)?.to_string(),
                kind: ArtifactKind::parse(get(c_kind)?)?,
                variant,
                batch: get(c_batch)?.parse()?,
                n: get(c_n)?.parse()?,
                dtype: Dtype::parse(get(c_dtype)?)?,
                descending: get(c_desc)? == "1",
                block: get(c_block)?.parse()?,
                grid_cells: get(c_cells)?.parse()?,
                file: PathBuf::from(get(c_file)?),
            });
        }
        if entries.is_empty() {
            crate::bail!("manifest has no artifacts");
        }
        Ok(Self { dir, entries })
    }

    /// Find the sort artifact exactly matching the query.
    pub fn find(
        &self,
        variant: Variant,
        batch: usize,
        n: usize,
        dtype: Dtype,
        descending: bool,
    ) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|a| {
            a.kind == ArtifactKind::Sort
                && a.variant == variant
                && a.batch == batch
                && a.n == n
                && a.dtype == dtype
                && a.descending == descending
        })
    }

    /// All ascending-u32 *sort* artifacts of one variant (the service's
    /// menu), sorted by (n, batch).
    pub fn size_classes(&self, variant: Variant) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .entries
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Sort
                    && a.variant == variant
                    && a.dtype == Dtype::U32
                    && !a.descending
            })
            .collect();
        v.sort_by_key(|a| (a.n, a.batch));
        v
    }

    /// All ascending-u32 *merge* artifacts, sorted by (n, batch) — the
    /// hybrid sorter's merge-tree menu.
    pub fn merge_classes(&self) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .entries
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Merge && a.dtype == Dtype::U32 && !a.descending
            })
            .collect();
        v.sort_by_key(|a| (a.n, a.batch));
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Statically audit this manifest and its HLO files for shape,
    /// dtype and order drift, dangling files and duplicate classes —
    /// pass 3 of the plan verifier. See
    /// [`crate::analysis::artifact_check::audit_manifest`].
    pub fn analyze(&self) -> crate::analysis::Report {
        crate::analysis::artifact_check::audit_manifest(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile\n\
        sort_basic_b1_n1024_uint32_asc\tsort\tbasic\t1\t1024\tuint32\t0\t256\t16\ta.hlo.txt\n\
        sort_optimized_b8_n4096_uint32_asc\tsort\toptimized\t8\t4096\tuint32\t0\t256\t16\tb.hlo.txt\n\
        sort_optimized_b8_n4096_float32_asc\tsort\toptimized\t8\t4096\tfloat32\t0\t256\t16\tc.hlo.txt\n\
        sort_optimized_b8_n4096_uint32_desc\tsort\toptimized\t8\t4096\tuint32\t1\t256\t16\td.hlo.txt\n\
        merge_optimized_b1_n8192_uint32_asc\tmerge\toptimized\t1\t8192\tuint32\t0\t4096\t4\te.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(PathBuf::from("/x"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 5);
        assert_eq!(m.entries[0].variant, Variant::Basic);
        assert_eq!(m.entries[0].kind, ArtifactKind::Sort);
        assert_eq!(m.entries[0].n, 1024);
        assert!(!m.entries[0].descending);
        assert!(m.entries[3].descending);
        assert_eq!(m.entries[4].kind, ArtifactKind::Merge);
        assert_eq!(m.path_of(&m.entries[1]), PathBuf::from("/x/b.hlo.txt"));
    }

    #[test]
    fn load_merged_unions_menus_with_primary_winning() {
        let base = std::env::temp_dir().join(format!(
            "bitonic-manifest-merge-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        let (primary, extra) = (base.join("fixture"), base.join("generated"));
        std::fs::create_dir_all(&primary).unwrap();
        std::fs::create_dir_all(&extra).unwrap();
        const HEADER: &str =
            "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile\n";
        std::fs::write(
            primary.join("manifest.tsv"),
            format!("{HEADER}sort_optimized_b1_n1024_uint32_asc\tsort\toptimized\t1\t1024\tuint32\t0\t256\t4\tfix.hlo.txt\n"),
        )
        .unwrap();
        // The generated dir re-lists the fixture's class (must lose)
        // plus a genuinely new 1M class (must join the menu).
        std::fs::write(
            extra.join("manifest.tsv"),
            format!(
                "{HEADER}sort_optimized_b1_n1024_uint32_asc\tsort\toptimized\t1\t1024\tuint32\t0\t256\t4\tdup.hlo.txt\n\
                 sort_optimized_b1_n1048576_uint32_asc\tsort\toptimized\t1\t1048576\tuint32\t0\t256\t4096\tbig.hlo.txt\n"
            ),
        )
        .unwrap();
        let m = Manifest::load_merged(&primary, &extra).unwrap();
        assert_eq!(m.dir, primary);
        assert_eq!(m.entries.len(), 2);
        // Collision resolved in the fixture's favour.
        let small = m
            .find(Variant::Optimized, 1, 1024, Dtype::U32, false)
            .unwrap();
        assert_eq!(m.path_of(small), primary.join("fix.hlo.txt"));
        // Merged-in entry resolves into the generated dir even though
        // path_of joins against the primary dir (absolute file path).
        let big = m
            .find(Variant::Optimized, 1, 1 << 20, Dtype::U32, false)
            .unwrap();
        assert_eq!(m.path_of(big), extra.join("big.hlo.txt"));
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn merge_classes_filtered() {
        let m = Manifest::parse(PathBuf::from("/x"), SAMPLE).unwrap();
        let merges = m.merge_classes();
        assert_eq!(merges.len(), 1);
        assert_eq!(merges[0].n, 8192);
        // find() never returns merges.
        assert!(m
            .find(Variant::Optimized, 1, 8192, Dtype::U32, false)
            .is_none());
    }

    #[test]
    fn find_is_exact() {
        let m = Manifest::parse(PathBuf::from("/x"), SAMPLE).unwrap();
        assert!(m
            .find(Variant::Optimized, 8, 4096, Dtype::U32, false)
            .is_some());
        assert!(m
            .find(Variant::Optimized, 8, 4096, Dtype::U32, true)
            .is_some());
        assert!(m.find(Variant::Semi, 8, 4096, Dtype::U32, false).is_none());
        assert!(m.find(Variant::Optimized, 4, 4096, Dtype::U32, false).is_none());
    }

    #[test]
    fn size_classes_filtered_and_sorted() {
        let m = Manifest::parse(PathBuf::from("/x"), SAMPLE).unwrap();
        let classes = m.size_classes(Variant::Optimized);
        assert_eq!(classes.len(), 1); // f32 and desc excluded
        assert_eq!(classes[0].n, 4096);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse(PathBuf::from("/x"), "").is_err());
        assert!(Manifest::parse(PathBuf::from("/x"), "bogus\nrow").is_err());
        let bad_variant = "name\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile\nx\twat\t1\t2\tuint32\t0\t2\t1\tf\n";
        assert!(Manifest::parse(PathBuf::from("/x"), bad_variant).is_err());
    }

    #[test]
    fn dtype_roundtrip() {
        for d in [Dtype::U32, Dtype::I32, Dtype::F32] {
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
        }
        assert!(Dtype::parse("float64").is_err());
        // XLA's HLO spelling: int32 is s32.
        assert_eq!(Dtype::U32.hlo_token(), "u32");
        assert_eq!(Dtype::I32.hlo_token(), "s32");
        assert_eq!(Dtype::F32.hlo_token(), "f32");
    }
}
