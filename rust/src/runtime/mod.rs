//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//!
//! ```text
//! artifacts/manifest.tsv ──> Registry (metadata)
//! artifacts/<name>.hlo.txt ─ HloModuleProto::from_text_file
//!                          ─ XlaComputation::from_proto
//!                          ─ PjRtClient::cpu().compile()   (once, cached)
//!                          ─ executable.execute(&[literal]) (hot path)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and the aot.py docstring).
//!
//! Python never runs here — the artifacts directory is the entire
//! build-time/run-time interface.

pub mod artifact;
pub mod executor;
pub mod host;
pub mod registry;

pub use artifact::{ArtifactKind, ArtifactMeta, Dtype, Manifest};
pub use executor::SortExecutor;
pub use host::{spawn as spawn_device_host, DeviceHandle};
pub use registry::{Key, Registry};
