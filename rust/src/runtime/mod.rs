//! Artifact runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Flow:
//!
//! ```text
//! artifacts/manifest.tsv ──> Registry (metadata)
//! artifacts/<name>.hlo.txt ─ SortExecutor::compile (load + validate +
//!                            precompute ExecutionPlan, once, cached)
//!                          ─ executor.sort_*()      (hot path: pure walk
//!                            over the plan, row-parallel on the shared
//!                            ThreadPool when the host is configured
//!                            with threads > 1)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that older PJRT bindings reject; the text
//! parser reassigns ids (see DESIGN.md and the aot.py docstring). The
//! execution backend is currently a deterministic native-CPU engine (see
//! [`executor`]) because the `xla` PJRT bindings are not vendored in this
//! offline environment; the module boundary is unchanged, so swapping
//! PJRT back in touches only `executor.rs`.
//!
//! Python never runs here — the artifacts directory is the entire
//! build-time/run-time interface.

pub mod artifact;
pub mod autotune;
pub mod executor;
pub mod genart;
pub mod host;
pub mod registry;

pub use artifact::{ArtifactKind, ArtifactMeta, Dtype, Manifest};
pub use genart::{generate as generate_artifacts, GenReport, GenSpec};
pub use autotune::{
    tune, tune_tiles, PlanPolicy, TileEntry, TileProfile, TuneOutcome, TuneRequest, TunedEntry,
    TuningProfile,
};
pub use executor::{
    effective_interleave, ExecutionPlan, PlanConfig, SortExecutor, DEFAULT_PLAN_BLOCK,
    DEFAULT_PLAN_INTERLEAVE,
};
pub use host::{
    spawn as spawn_device_host, spawn_discovered as spawn_device_host_discovered,
    spawn_with as spawn_device_host_with, DeviceHandle, HostConfig,
};
pub use registry::{Key, Registry};

/// Resolve the artifacts directory used by drivers that do not take an
/// explicit path: `$ARTIFACTS_DIR` if set, else `./artifacts` (a local
/// `compile.aot` run), else the checked-in `rust/artifacts/` fixture
/// next to this crate (resolved at compile time, so it works from any
/// working directory on the build machine).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("ARTIFACTS_DIR") {
        return std::path::PathBuf::from(dir);
    }
    let local = std::path::PathBuf::from("artifacts");
    if local.join("manifest.tsv").exists() {
        return local;
    }
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Resolve the *generated* artifacts directory merged on top of the
/// checked-in fixture menu: `$BITONIC_GEN_ARTIFACTS` if set, else
/// `<primary>/generated` (gitignored; written by
/// `bitonic-tpu gen-artifacts`). Returns `None` when no generated
/// manifest exists — discovery then falls back to the single-dir path.
pub fn generated_artifacts_dir(primary: &std::path::Path) -> Option<std::path::PathBuf> {
    let dir = match std::env::var("BITONIC_GEN_ARTIFACTS") {
        Ok(d) if !d.is_empty() => std::path::PathBuf::from(d),
        _ => primary.join("generated"),
    };
    dir.join("manifest.tsv").exists().then_some(dir)
}
