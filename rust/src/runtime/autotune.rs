//! Per-host plan autotuning: sweep `PlanConfig { block, interleave }` ×
//! worker threads × comparator ISA ([`crate::sort::simd::KernelIsa`]) on
//! the **real executor** and persist the fastest configuration per
//! `(n, dtype)` size class.
//!
//! The paper tunes its kernels to one fixed device (a K10's 48 KiB of
//! shared memory fixes `block`); this crate runs on whatever CPU hosts
//! it, where the right fused-tile size and batch-interleave width depend
//! on cache sizes and vector width. ROADMAP's "auto-tune `block` per
//! host" item lands here:
//!
//! * [`tune`] measures every candidate through
//!   `runtime::executor::execute_batch` — the exact dispatch path the
//!   serving stack runs, pool included — and picks the highest rows/sec
//!   per class.
//! * [`TuningProfile`] persists the choices as a TSV next to the
//!   artifacts (`<artifacts>/autotune.tsv` by default, see
//!   [`TuningProfile::default_path`]), one line per `(n, dtype)` class.
//! * [`PlanPolicy`] is how the profile is consulted: the
//!   [`crate::runtime::Registry`] resolves each artifact's effective
//!   [`PlanConfig`] through it when compiling the executor, with
//!   operator-pinned fields (explicit `--plan-block` /
//!   `--plan-interleave`) always winning over the profile.
//!
//! CLI: `bitonic-tpu tune [--smoke]` runs the sweep and writes the
//! profile; `sort`/`serve` pick it up automatically, and the survey
//! bench (`bitonic-tpu bench`, [`crate::bench::matrix`]) routes its
//! device substrate through the same resolved policy — so the numbers
//! recorded in `BENCH_trajectory.json` are the tuned configuration's,
//! not a hardcoded default's.
//!
//! **Scope of a tuned entry.** `block`/`interleave` are resolved per
//! class and re-narrowed against the live batch at dispatch, so a tuned
//! width degrades gracefully when the serving batch differs from the
//! measured one (the CLI measures at the menu's largest batch for this
//! reason). The `threads` column is a *host-pool recommendation*: the
//! device host owns one pool for all classes (single-device-owner
//! model), so [`PlanPolicy::tuned_threads`] takes the max over entries —
//! a class whose best measurement was serial still runs on the shared
//! pool, where the narrowing keeps its tiles worker-aligned. Per-class
//! pool sizing would need per-batch pools the runtime deliberately does
//! not have (see ROADMAP).

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::bench::{black_box, Bench};
use crate::sort::hybrid::HierarchicalSorter;
use crate::sort::network::Variant;
use crate::sort::simd::{KernelChoice, KernelIsa};
use crate::sort::SortKey;
use crate::util::error::Context;
use crate::util::threadpool::ThreadPool;
use crate::workload::{Distribution, Generator};

use super::artifact::{ArtifactKind, Dtype, Manifest};
use super::executor::{effective_interleave, execute_batch, ExecutionPlan, PlanConfig};
use super::host::DeviceHandle;

/// One measured (or chosen) tuning point: the fastest known executor
/// configuration for a `(n, dtype)` size class on this host.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedEntry {
    /// Row length of the size class.
    pub n: usize,
    /// Key dtype of the size class.
    pub dtype: Dtype,
    /// Launch-fusion variant measured (the sweep stays on `Optimized`;
    /// recorded so the TSV is self-describing).
    pub variant: Variant,
    /// Fused-tile block, in keys.
    pub block: usize,
    /// Batch-interleave width R.
    pub interleave: usize,
    /// Executor pool threads the measurement used (1 = serial).
    pub threads: usize,
    /// Comparator ISA the measurement ran (`scalar` for profiles written
    /// before the axis existed — their sweeps only ran the scalar
    /// kernels).
    pub isa: KernelIsa,
    /// Measured throughput, rows per second.
    pub rows_per_sec: f64,
}

/// A persisted set of per-class tuning choices (`autotune.tsv`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuningProfile {
    /// One chosen entry per `(n, dtype)` class.
    pub entries: Vec<TunedEntry>,
}

const PROFILE_HEADER: &str = "n\tdtype\tvariant\tblock\tinterleave\tthreads\tisa\trows_per_sec";

impl TuningProfile {
    /// Canonical profile location for an artifacts directory: the sweep
    /// is a property of (host, artifact menu), so the profile lives next
    /// to the manifest it tunes for.
    pub fn default_path(artifacts_dir: impl AsRef<Path>) -> PathBuf {
        artifacts_dir.as_ref().join("autotune.tsv")
    }

    /// Load a profile TSV, validating every row (a hand-edited file must
    /// fail loudly here, not deep inside plan compilation).
    ///
    /// Both schema generations load: the original 7-field format (no
    /// `isa` column — those sweeps only ran the scalar kernels, so the
    /// column defaults to `scalar`) and the current 8-field one. An
    /// upgrade must never silently invalidate an existing profile.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tuning profile {path:?} — generate one with `bitonic-tpu tune`"))?;
        const LEGACY_HEADER: &str = "n\tdtype\tvariant\tblock\tinterleave\tthreads\trows_per_sec";
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty()
                || line.starts_with('#')
                || line == PROFILE_HEADER
                || line == LEGACY_HEADER
            {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            crate::ensure!(
                f.len() == 7 || f.len() == 8,
                "tuning profile {path:?} line {}: want 7 (pre-isa) or 8 tab-separated fields, \
                 got {}",
                lineno + 1,
                f.len()
            );
            // In the 8-field format the isa column sits before
            // rows_per_sec; in the legacy one rows_per_sec is field 6.
            let (isa, rps) = if f.len() == 8 {
                let isa = KernelIsa::parse(f[6]).with_context(|| {
                    format!("tuning profile {path:?} line {}: bad isa {:?}", lineno + 1, f[6])
                })?;
                (isa, f[7])
            } else {
                (KernelIsa::Scalar, f[6])
            };
            let entry = TunedEntry {
                n: f[0].parse().with_context(|| format!("line {}: n", lineno + 1))?,
                dtype: Dtype::parse(f[1])?,
                variant: Variant::parse(f[2])
                    .with_context(|| format!("line {}: bad variant {:?}", lineno + 1, f[2]))?,
                block: f[3].parse().with_context(|| format!("line {}: block", lineno + 1))?,
                interleave: f[4]
                    .parse()
                    .with_context(|| format!("line {}: interleave", lineno + 1))?,
                threads: f[5].parse().with_context(|| format!("line {}: threads", lineno + 1))?,
                isa,
                rows_per_sec: rps
                    .parse()
                    .with_context(|| format!("line {}: rows_per_sec", lineno + 1))?,
            };
            crate::ensure!(
                entry.n.is_power_of_two()
                    && entry.block.is_power_of_two()
                    && entry.block >= 2
                    && entry.interleave >= 1
                    && entry.threads >= 1,
                "tuning profile {path:?} line {}: malformed entry {entry:?}",
                lineno + 1
            );
            entries.push(entry);
        }
        Ok(Self { entries })
    }

    /// Write the profile TSV.
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        let mut out = String::from("# bitonic-tpu tuning profile — written by `bitonic-tpu tune`\n");
        out.push_str(PROFILE_HEADER);
        out.push('\n');
        for e in &self.entries {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:.1}\n",
                e.n,
                e.dtype.name(),
                e.variant.name(),
                e.block,
                e.interleave,
                e.threads,
                e.isa.name(),
                e.rows_per_sec
            ));
        }
        std::fs::write(path, out).with_context(|| format!("writing tuning profile {path:?}"))
    }

    /// The tuned entry for a size class: an exact `(n, dtype)` match,
    /// else the nearest same-dtype class with `entry.n >= n` (its cache
    /// trade-offs dominate ours), else the largest same-dtype class.
    ///
    /// When the final fallback reaches *down* more than 4× (a generated
    /// mega-class served off a profile tuned only up to the fixture
    /// ceiling, say), the choice is logged with its distance — the
    /// silent version of this stranded exactly that case before the
    /// menu could outgrow the profile.
    pub fn lookup(&self, n: usize, dtype: Dtype) -> Option<&TunedEntry> {
        let e = self.lookup_quiet(n, dtype)?;
        if let Some(factor) = Self::fallback_shortfall(e, n) {
            eprintln!(
                "WARN autotune: no tuned class for n={n} dtype={}; \
                 falling back to n={} — {factor}x smaller (re-run `bitonic-tpu tune` \
                 after extending the artifact menu)",
                dtype.name(),
                e.n,
            );
        }
        Some(e)
    }

    /// [`TuningProfile::lookup`] without the distance WARN (tests and
    /// callers that report the shortfall themselves).
    pub fn lookup_quiet(&self, n: usize, dtype: Dtype) -> Option<&TunedEntry> {
        let same: Vec<&TunedEntry> = self.entries.iter().filter(|e| e.dtype == dtype).collect();
        same.iter()
            .find(|e| e.n == n)
            .copied()
            .or_else(|| same.iter().filter(|e| e.n >= n).min_by_key(|e| e.n).copied())
            .or_else(|| same.iter().max_by_key(|e| e.n).copied())
    }

    /// `Some(n / entry.n)` when the class `lookup` settled on is more
    /// than 4× smaller than the requested `n` — the fallback distance
    /// worth warning about. `None` for exact, larger, or near misses.
    pub fn fallback_shortfall(entry: &TunedEntry, n: usize) -> Option<usize> {
        (entry.n.saturating_mul(4) < n).then(|| n / entry.n)
    }

    /// The pool size the profile recommends for a host serving every
    /// class (the max over entries — a pool can idle, not grow).
    pub fn tuned_threads(&self) -> Option<usize> {
        self.entries.iter().map(|e| e.threads).max()
    }

    /// Audit this profile against a manifest: a tuned class no artifact
    /// serves any more (the menu was regenerated since the sweep) is
    /// **stale** — the policy's nearest-class fallback makes it harmless
    /// at plan resolution, so it must warn-and-continue here, never
    /// panic or fail the verifier. Pinned by the stale-profile
    /// regression test in `rust/tests/analysis_mutations.rs`.
    pub fn analyze(&self, manifest: &super::artifact::Manifest) -> crate::analysis::Report {
        use crate::analysis::Verdict;
        let mut report = crate::analysis::Report::new();
        let mut stale = 0usize;
        for e in &self.entries {
            let served = manifest
                .entries
                .iter()
                .any(|m| m.kind == ArtifactKind::Sort && m.n == e.n && m.dtype == e.dtype);
            if !served {
                stale += 1;
                report.push(
                    "artifact.autotune",
                    format!("n={} dtype={}", e.n, e.dtype.name()),
                    Verdict::Warn,
                    "tuned class has no sort artifact in the manifest (stale profile); \
                     plan resolution falls back to the nearest class",
                );
            }
        }
        report.push(
            "artifact.autotune",
            "autotune.tsv",
            Verdict::Pass,
            format!(
                "{}/{} tuned classes match a manifest sort class ({stale} stale tolerated)",
                self.entries.len() - stale,
                self.entries.len()
            ),
        );
        report
    }
}

/// How the registry picks each artifact's effective [`PlanConfig`]: a
/// base configuration (CLI flags or defaults), optionally refined per
/// `(n, dtype)` class by a [`TuningProfile`] — except for fields the
/// operator pinned explicitly, which always win. This is the seam the
/// coordinator needed to run different plan configs per size class
/// instead of one global default.
#[derive(Clone, Debug, Default)]
pub struct PlanPolicy {
    /// Fallback / operator-chosen configuration.
    pub base: PlanConfig,
    /// Tuned per-class choices, when a profile exists.
    pub profile: Option<TuningProfile>,
    /// `--plan-block` was given explicitly: the profile must not override.
    pub pin_block: bool,
    /// `--plan-interleave` was given explicitly: ditto.
    pub pin_interleave: bool,
    /// `--kernel` was given explicitly: ditto.
    pub pin_kernel: bool,
}

impl PlanPolicy {
    /// A policy that always resolves to `base` (no profile consulted).
    pub fn fixed(base: PlanConfig) -> Self {
        Self {
            base,
            profile: None,
            pin_block: true,
            pin_interleave: true,
            pin_kernel: true,
        }
    }

    /// A policy that refines `base` per class from `profile`.
    pub fn tuned(base: PlanConfig, profile: TuningProfile) -> Self {
        Self {
            base,
            profile: Some(profile),
            pin_block: false,
            pin_interleave: false,
            pin_kernel: false,
        }
    }

    /// The effective plan configuration for one `(n, dtype)` class.
    pub fn resolve(&self, n: usize, dtype: Dtype) -> PlanConfig {
        let mut cfg = self.base;
        if let Some(profile) = &self.profile {
            if let Some(e) = profile.lookup(n, dtype) {
                if !self.pin_block {
                    cfg.block = e.block;
                }
                if !self.pin_interleave {
                    cfg.interleave = e.interleave;
                }
                // A tuned ISA this host can't run (profile copied from
                // another machine, or the `simd` feature toggled off) is
                // ignored rather than failing plan compilation — the
                // base choice stands.
                if !self.pin_kernel && e.isa.available() {
                    cfg.kernel = KernelChoice::Fixed(e.isa);
                }
            }
        }
        cfg
    }

    /// Pool size the profile recommends, if tuned.
    pub fn tuned_threads(&self) -> Option<usize> {
        self.profile.as_ref().and_then(TuningProfile::tuned_threads)
    }
}

impl From<PlanConfig> for PlanPolicy {
    fn from(base: PlanConfig) -> Self {
        Self::fixed(base)
    }
}

/// One measured tile-size candidate for the hierarchical mega-sort path.
#[derive(Clone, Debug, PartialEq)]
pub struct TileEntry {
    /// Total input length the measurement sorted.
    pub n: usize,
    /// Tile size chosen (device-sorted run length; a menu sort class).
    pub tile: usize,
    /// Merge workers the measurement used (1 = serial loser-tree merge;
    /// more = the splitter-partitioned parallel merge of
    /// [`crate::sort::pmerge`]).
    pub merge_threads: usize,
    /// Measured throughput, keys per second.
    pub keys_per_sec: f64,
}

/// The autotuner's **tile + merge axes**: persisted tile-size and
/// merge-parallelism choices for [`crate::sort::HierarchicalSorter`],
/// one line per mega-sort length. Lives in its own TSV
/// (`autotune_hier.tsv`) so the strict plan-profile format stays
/// byte-stable for existing tooling.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TileProfile {
    /// One chosen entry per measured total length.
    pub entries: Vec<TileEntry>,
}

const TILE_HEADER: &str = "n\ttile\tmerge_threads\tkeys_per_sec";
const LEGACY_TILE_HEADER: &str = "n\ttile\tkeys_per_sec";

impl TileProfile {
    /// Canonical location next to the plan profile: `<artifacts>/autotune_hier.tsv`.
    pub fn default_path(artifacts_dir: impl AsRef<Path>) -> PathBuf {
        artifacts_dir.as_ref().join("autotune_hier.tsv")
    }

    /// Load and validate a tile profile TSV.
    ///
    /// Both schema generations load: the original 3-field format (no
    /// `merge_threads` column — those sweeps only ran the serial merge,
    /// so the column defaults to 1) and the current 4-field one. An
    /// upgrade must never silently invalidate an existing profile —
    /// the same compatibility contract as [`TuningProfile::load`].
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading tile profile {path:?} — generate one with `bitonic-tpu tune --hier`")
        })?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty()
                || line.starts_with('#')
                || line == TILE_HEADER
                || line == LEGACY_TILE_HEADER
            {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            crate::ensure!(
                f.len() == 3 || f.len() == 4,
                "tile profile {path:?} line {}: want 3 (pre-merge-axis) or 4 tab-separated \
                 fields, got {}",
                lineno + 1,
                f.len()
            );
            // In the 4-field format merge_threads sits before
            // keys_per_sec; legacy rows measured the serial merge.
            let (merge_threads, kps) = if f.len() == 4 {
                let mt: usize = f[2]
                    .parse()
                    .with_context(|| format!("line {}: merge_threads", lineno + 1))?;
                (mt, f[3])
            } else {
                (1, f[2])
            };
            let entry = TileEntry {
                n: f[0].parse().with_context(|| format!("line {}: n", lineno + 1))?,
                tile: f[1].parse().with_context(|| format!("line {}: tile", lineno + 1))?,
                merge_threads,
                keys_per_sec: kps
                    .parse()
                    .with_context(|| format!("line {}: keys_per_sec", lineno + 1))?,
            };
            crate::ensure!(
                entry.n.is_power_of_two()
                    && entry.tile.is_power_of_two()
                    && entry.tile >= 2
                    && entry.tile <= entry.n
                    && entry.merge_threads >= 1,
                "tile profile {path:?} line {}: malformed entry {entry:?}",
                lineno + 1
            );
            entries.push(entry);
        }
        Ok(Self { entries })
    }

    /// Write the tile profile TSV.
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        let mut out =
            String::from("# bitonic-tpu tile profile — written by `bitonic-tpu tune --hier`\n");
        out.push_str(TILE_HEADER);
        out.push('\n');
        for e in &self.entries {
            out.push_str(&format!(
                "{}\t{}\t{}\t{:.1}\n",
                e.n, e.tile, e.merge_threads, e.keys_per_sec
            ));
        }
        std::fs::write(path, out).with_context(|| format!("writing tile profile {path:?}"))
    }

    /// The tuned entry for a mega-sort of `n` keys: exact match, else
    /// the nearest measured length above `n`, else the largest measured
    /// length — the same fallback ladder as [`TuningProfile::lookup`].
    pub fn lookup_entry(&self, n: usize) -> Option<&TileEntry> {
        self.entries
            .iter()
            .find(|e| e.n == n)
            .or_else(|| self.entries.iter().filter(|e| e.n >= n).min_by_key(|e| e.n))
            .or_else(|| self.entries.iter().max_by_key(|e| e.n))
    }

    /// The tuned tile size alone (see [`TileProfile::lookup_entry`]).
    pub fn lookup(&self, n: usize) -> Option<usize> {
        self.lookup_entry(n).map(|e| e.tile)
    }
}

/// Sweep the tile and merge axes: for every requested total length,
/// sort a fresh uniform input through a [`HierarchicalSorter`] per
/// candidate (tile class, merge-thread count) and keep the fastest. The
/// measurement runs the real device-host dispatch path — batched tile
/// sorts plus the (serial or splitter-partitioned parallel) merge — so
/// the persisted choice reflects the whole pipeline, not just the
/// kernel. `merge_grid` lists the merge-worker candidates (1 = the
/// serial loser-tree merge; it is always measured even if absent from
/// the grid, so the profile can never regress below the serial
/// baseline).
pub fn tune_tiles(
    handle: &DeviceHandle,
    manifest: &Manifest,
    ns: &[usize],
    merge_grid: &[usize],
    bench: &Bench,
    seed: u64,
) -> crate::Result<TileProfile> {
    let mut menu: Vec<usize> = manifest
        .size_classes(Variant::Optimized)
        .into_iter()
        .map(|m| m.n)
        .collect();
    menu.sort_unstable();
    menu.dedup();
    let mut merge_candidates: Vec<usize> =
        merge_grid.iter().map(|&t| t.max(1)).chain([1]).collect();
    merge_candidates.sort_unstable();
    merge_candidates.dedup();
    let mut entries = Vec::new();
    for &n in ns {
        let candidates: Vec<usize> = menu.iter().copied().filter(|&t| t <= n).collect();
        crate::ensure!(
            !candidates.is_empty(),
            "tune-tiles: no sort class fits inside n={n}"
        );
        let mut best: Option<TileEntry> = None;
        for &tile in &candidates {
            for &merge_threads in &merge_candidates {
                let sorter = HierarchicalSorter::with_tile(
                    handle.clone(),
                    manifest,
                    Variant::Optimized,
                    tile,
                )?
                .with_merge_threads(merge_threads);
                let mut gen = Generator::new(seed);
                let label = format!("tune-tiles n={n} tile={tile} merge={merge_threads}");
                let meas = bench.run_with_setup(
                    &label,
                    &mut || gen.u32s(n, Distribution::Uniform),
                    |mut data| {
                        sorter.sort(&mut data).expect("tile sweep sort must execute");
                        black_box(&data);
                    },
                );
                let secs = meas.median_ns() as f64 / 1e9;
                let keys_per_sec = if secs > 0.0 { n as f64 / secs } else { f64::MAX };
                let entry = TileEntry { n, tile, merge_threads, keys_per_sec };
                if best
                    .as_ref()
                    .is_none_or(|b| entry.keys_per_sec > b.keys_per_sec)
                {
                    best = Some(entry.clone());
                }
            }
        }
        entries.push(best.expect("tune-tiles: empty candidate grid"));
    }
    Ok(TileProfile { entries })
}

/// One sweep request: which classes to tune and the candidate grid.
#[derive(Clone, Debug)]
pub struct TuneRequest {
    /// `(n, dtype)` size classes to tune (usually the manifest's menu).
    pub classes: Vec<(usize, Dtype)>,
    /// Candidate fused-tile blocks (keys; clamped to each class's n).
    pub blocks: Vec<usize>,
    /// Candidate batch-interleave widths R.
    pub interleaves: Vec<usize>,
    /// Candidate executor pool sizes (1 = serial).
    pub threads: Vec<usize>,
    /// Candidate comparator ISAs (unavailable ones are skipped, so a
    /// request built on one host replays safely on another).
    pub isas: Vec<KernelIsa>,
    /// Rows per measured batch.
    pub rows: usize,
    /// Measurement harness preset.
    pub bench: Bench,
    /// Workload seed (measurements are deterministic in input).
    pub seed: u64,
}

impl TuneRequest {
    /// Tiny grid for CI smoke: terminates in seconds, still exercises
    /// the full sweep → choose → persist pipeline.
    pub fn smoke(classes: Vec<(usize, Dtype)>) -> Self {
        Self {
            classes,
            blocks: vec![1024],
            interleaves: vec![1, 8],
            threads: vec![1],
            isas: vec![KernelIsa::Scalar],
            rows: 8,
            bench: Bench {
                warmup: 1,
                min_iters: 2,
                max_iters: 6,
                target: Duration::from_millis(150),
            },
            seed: 0x7E57,
        }
    }

    /// The real per-host grid: L2-to-L1 block range × the interleave
    /// widths a 128/256/512-bit SIMD unit can saturate × serial vs one
    /// pool sized to the machine × every comparator ISA this host can
    /// execute (so the profile can record that autovectorized scalar
    /// beats the explicit kernels for a class, where it does).
    pub fn full(classes: Vec<(usize, Dtype)>) -> Self {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
        Self {
            classes,
            blocks: vec![256, 1024, 4096],
            interleaves: vec![1, 4, 8, 16],
            threads: if avail > 1 { vec![1, avail] } else { vec![1] },
            isas: KernelIsa::available_isas(),
            rows: 32,
            bench: Bench {
                warmup: 1,
                min_iters: 2,
                max_iters: 10,
                target: Duration::from_millis(250),
            },
            seed: 0x7E57,
        }
    }
}

/// Everything a sweep produced: the chosen profile plus every point
/// measured (for reports and the bench trajectory JSON).
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Fastest config per class — what [`TuningProfile::save`] persists.
    pub profile: TuningProfile,
    /// All measured candidates, sweep order.
    pub measured: Vec<TunedEntry>,
}

/// Run the sweep: for every class, measure every candidate
/// `(block, interleave, threads, isa)` on the real executor dispatch
/// path and keep the fastest.
pub fn tune(req: &TuneRequest) -> TuneOutcome {
    let mut measured = Vec::new();
    let mut chosen = Vec::new();
    // Unavailable ISAs are dropped, not errors: a request literal with
    // `avx2` must replay on a host without it (it measures what it can).
    let isas: Vec<KernelIsa> = req.isas.iter().copied().filter(|i| i.available()).collect();
    for &(n, dtype) in &req.classes {
        let mut best: Option<TunedEntry> = None;
        for &threads in &req.threads {
            let pool = (threads > 1).then(|| ThreadPool::new(threads, 2 * threads));
            let mut blocks: Vec<usize> = req.blocks.iter().map(|&b| b.min(n).max(2)).collect();
            blocks.sort_unstable();
            blocks.dedup();
            // Candidate widths reduced to the *effective* width this
            // (rows, threads) combination executes — the exact narrowing
            // `execute_batch` applies, via the shared
            // [`effective_interleave`]. Deduping after the reduction
            // avoids re-measuring identical code paths, and the persisted
            // entry records a width that actually ran.
            let mut widths: Vec<usize> = req
                .interleaves
                .iter()
                .map(|&r| effective_interleave(r, req.rows, threads))
                .collect();
            widths.sort_unstable();
            widths.dedup();
            for &block in &blocks {
                for &interleave in &widths {
                    for &isa in &isas {
                        let plan = ExecutionPlan::with_config(
                            ArtifactKind::Sort,
                            n,
                            false,
                            PlanConfig {
                                variant: Variant::Optimized,
                                block,
                                interleave,
                                kernel: KernelChoice::Fixed(isa),
                            },
                        );
                        let rows_per_sec = measure_rows_per_sec(
                            &plan,
                            pool.as_ref(),
                            dtype,
                            req.rows,
                            &req.bench,
                            req.seed,
                        );
                        let entry = TunedEntry {
                            n,
                            dtype,
                            variant: Variant::Optimized,
                            block,
                            interleave,
                            threads,
                            isa,
                            rows_per_sec,
                        };
                        if best.as_ref().is_none_or(|b| entry.rows_per_sec > b.rows_per_sec) {
                            best = Some(entry.clone());
                        }
                        measured.push(entry);
                    }
                }
            }
        }
        chosen.push(best.expect("tune(): empty candidate grid"));
    }
    TuneOutcome {
        profile: TuningProfile { entries: chosen },
        measured,
    }
}

/// Measure one candidate: rows/sec sorting a fresh `rows × n` batch per
/// iteration through [`execute_batch`] — the serving path's dispatch,
/// including pool and interleave tiling.
fn measure_rows_per_sec(
    plan: &ExecutionPlan,
    pool: Option<&ThreadPool>,
    dtype: Dtype,
    rows: usize,
    bench: &Bench,
    seed: u64,
) -> f64 {
    fn go<T: SortKey>(
        plan: &ExecutionPlan,
        pool: Option<&ThreadPool>,
        rows: usize,
        bench: &Bench,
        mut make: impl FnMut() -> Vec<T>,
    ) -> f64 {
        let cfg = plan.config();
        let label = format!(
            "tune n={} b={} r={} isa={}",
            plan.n(),
            cfg.block,
            cfg.interleave,
            plan.isa().name()
        );
        let meas = bench.run_with_setup(&label, &mut make, |mut data| {
            execute_batch(plan, pool, &mut data).expect("tune batch must execute");
            black_box(&data);
        });
        let secs = meas.median_ns() as f64 / 1e9;
        if secs > 0.0 {
            rows as f64 / secs
        } else {
            f64::MAX
        }
    }
    let n = plan.n();
    let mut gen = Generator::new(seed);
    match dtype {
        Dtype::U32 => go(plan, pool, rows, bench, || gen.u32s(rows * n, Distribution::Uniform)),
        Dtype::I32 => go(plan, pool, rows, bench, || {
            gen.u32s(rows * n, Distribution::Uniform)
                .into_iter()
                .map(|x| x as i32)
                .collect::<Vec<i32>>()
        }),
        Dtype::F32 => go(plan, pool, rows, bench, || gen.f32s(rows * n, Distribution::Uniform)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(n: usize, dtype: Dtype, block: usize, interleave: usize, threads: usize) -> TunedEntry {
        TunedEntry {
            n,
            dtype,
            variant: Variant::Optimized,
            block,
            interleave,
            threads,
            isa: KernelIsa::Scalar,
            rows_per_sec: 1000.0,
        }
    }

    #[test]
    fn profile_tsv_roundtrip() {
        let dir = std::env::temp_dir().join("bitonic-tpu-autotune-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.tsv");
        let profile = TuningProfile {
            entries: vec![
                entry(1024, Dtype::U32, 256, 8, 1),
                entry(65536, Dtype::U32, 4096, 16, 4),
                TunedEntry { isa: KernelIsa::Portable, ..entry(1024, Dtype::F32, 1024, 4, 2) },
            ],
        };
        profile.save(&path).unwrap();
        let loaded = TuningProfile::load(&path).unwrap();
        assert_eq!(loaded.entries.len(), 3);
        for (a, b) in loaded.entries.iter().zip(&profile.entries) {
            assert_eq!((a.n, a.dtype, a.block, a.interleave, a.threads, a.isa),
                       (b.n, b.dtype, b.block, b.interleave, b.threads, b.isa));
        }
        assert_eq!(loaded.tuned_threads(), Some(4));
    }

    /// Satellite regression: a 7-field profile written before the `isa`
    /// column existed must still load (defaulting to `scalar` — what
    /// those sweeps measured) and round-trip through the 8-field writer
    /// without changing any choice. No silent profile invalidation.
    #[test]
    fn legacy_seven_field_profiles_still_load() {
        let dir = std::env::temp_dir().join("bitonic-tpu-autotune-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.tsv");
        std::fs::write(
            &path,
            "# bitonic-tpu tuning profile — written by `bitonic-tpu tune`\n\
             n\tdtype\tvariant\tblock\tinterleave\tthreads\trows_per_sec\n\
             1024\tuint32\toptimized\t256\t8\t1\t1234.5\n\
             65536\tfloat32\toptimized\t4096\t16\t4\t99.0\n",
        )
        .unwrap();
        let loaded = TuningProfile::load(&path).unwrap();
        assert_eq!(loaded.entries.len(), 2);
        for e in &loaded.entries {
            assert_eq!(e.isa, KernelIsa::Scalar, "pre-isa rows measured the scalar kernels");
        }
        assert_eq!(
            (loaded.entries[0].n, loaded.entries[0].block, loaded.entries[0].rows_per_sec),
            (1024, 256, 1234.5)
        );
        // Saving upgrades the schema in place; the reload is identical.
        let upgraded = dir.join("legacy-upgraded.tsv");
        loaded.save(&upgraded).unwrap();
        let text = std::fs::read_to_string(&upgraded).unwrap();
        assert!(text.contains(PROFILE_HEADER), "save writes the 8-field header");
        assert_eq!(TuningProfile::load(&upgraded).unwrap(), loaded);
    }

    #[test]
    fn load_rejects_malformed_profiles() {
        let dir = std::env::temp_dir().join("bitonic-tpu-autotune-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.tsv");
        // block = 3 is not a power of two.
        std::fs::write(&bad, format!("{PROFILE_HEADER}\n1024\tuint32\toptimized\t3\t8\t1\t10.0\n"))
            .unwrap();
        assert!(TuningProfile::load(&bad).is_err());
        // interleave = 0 is rejected too.
        std::fs::write(&bad, format!("{PROFILE_HEADER}\n1024\tuint32\toptimized\t256\t0\t1\t10.0\n"))
            .unwrap();
        assert!(TuningProfile::load(&bad).is_err());
        // An unknown isa token is rejected with the column named.
        std::fs::write(
            &bad,
            format!("{PROFILE_HEADER}\n1024\tuint32\toptimized\t256\t8\t1\tneon\t10.0\n"),
        )
        .unwrap();
        let err = TuningProfile::load(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("bad isa"), "{err:#}");
        // Missing file names the tune command.
        let err = TuningProfile::load(dir.join("nope.tsv")).unwrap_err();
        assert!(format!("{err:#}").contains("bitonic-tpu tune"));
    }

    #[test]
    fn lookup_prefers_exact_then_next_larger_class() {
        let p = TuningProfile {
            entries: vec![
                entry(1024, Dtype::U32, 256, 4, 1),
                entry(16384, Dtype::U32, 1024, 8, 1),
                entry(1024, Dtype::F32, 512, 2, 1),
            ],
        };
        assert_eq!(p.lookup(1024, Dtype::U32).unwrap().block, 256);
        // Between classes: the next larger same-dtype class wins.
        assert_eq!(p.lookup(4096, Dtype::U32).unwrap().n, 16384);
        // Beyond every class: the largest same-dtype class.
        assert_eq!(p.lookup(1 << 20, Dtype::U32).unwrap().n, 16384);
        // Dtypes never cross.
        assert_eq!(p.lookup(1024, Dtype::F32).unwrap().block, 512);
        assert!(p.lookup(1024, Dtype::I32).is_none());
    }

    #[test]
    fn deep_fallback_reports_its_distance() {
        let p = TuningProfile {
            entries: vec![entry(1024, Dtype::U32, 256, 4, 1)],
        };
        // 1M served off a 1K-tuned profile: 1024x smaller — warn-worthy.
        let e = p.lookup_quiet(1 << 20, Dtype::U32).unwrap();
        assert_eq!(e.n, 1024);
        assert_eq!(TuningProfile::fallback_shortfall(e, 1 << 20), Some(1024));
        // Exactly 4x smaller is a near miss, not a warning.
        assert_eq!(TuningProfile::fallback_shortfall(e, 4096), None);
        assert_eq!(TuningProfile::fallback_shortfall(e, 8192), Some(8));
        // Exact and upward fallbacks never report a shortfall.
        assert_eq!(TuningProfile::fallback_shortfall(e, 1024), None);
        assert_eq!(TuningProfile::fallback_shortfall(e, 64), None);
        // The warning path returns the same entry as the quiet path.
        assert_eq!(p.lookup(1 << 20, Dtype::U32).unwrap().n, 1024);
    }

    #[test]
    fn tile_profile_roundtrip_and_lookup_ladder() {
        let dir = std::env::temp_dir().join("bitonic-tpu-autotune-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiles.tsv");
        let profile = TileProfile {
            entries: vec![
                TileEntry { n: 1 << 18, tile: 1 << 14, merge_threads: 1, keys_per_sec: 5e6 },
                TileEntry { n: 1 << 20, tile: 1 << 16, merge_threads: 4, keys_per_sec: 4e6 },
            ],
        };
        profile.save(&path).unwrap();
        let loaded = TileProfile::load(&path).unwrap();
        assert_eq!(loaded, profile);
        // Exact, next-larger, and beyond-the-top fallbacks.
        assert_eq!(loaded.lookup(1 << 18), Some(1 << 14));
        assert_eq!(loaded.lookup(1 << 19), Some(1 << 16));
        assert_eq!(loaded.lookup(1 << 24), Some(1 << 16));
        // The full entry rides the same ladder (merge axis included).
        assert_eq!(loaded.lookup_entry(1 << 19).unwrap().merge_threads, 4);
        assert_eq!(TileProfile::default().lookup(1 << 18), None);
        // tile > n is malformed.
        std::fs::write(&path, format!("{TILE_HEADER}\n1024\t4096\t1\t1.0\n")).unwrap();
        assert!(TileProfile::load(&path).is_err());
        // merge_threads = 0 is malformed.
        std::fs::write(&path, format!("{TILE_HEADER}\n4096\t1024\t0\t1.0\n")).unwrap();
        assert!(TileProfile::load(&path).is_err());
        // The missing-file error names the CLI that generates one.
        let err = TileProfile::load(dir.join("no-tiles.tsv")).unwrap_err();
        assert!(format!("{err:#}").contains("tune --hier"));
    }

    /// Satellite regression: a 3-field tile profile written before the
    /// merge-parallelism axis existed must still load (defaulting to the
    /// serial merge those sweeps measured) and round-trip through the
    /// 4-field writer without changing any choice.
    #[test]
    fn legacy_three_field_tile_profiles_still_load() {
        let dir = std::env::temp_dir().join("bitonic-tpu-autotune-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy-tiles.tsv");
        std::fs::write(
            &path,
            "# bitonic-tpu tile profile — written by `bitonic-tpu tune --hier`\n\
             n\ttile\tkeys_per_sec\n\
             262144\t16384\t5000000.0\n\
             1048576\t65536\t4000000.0\n",
        )
        .unwrap();
        let loaded = TileProfile::load(&path).unwrap();
        assert_eq!(loaded.entries.len(), 2);
        for e in &loaded.entries {
            assert_eq!(e.merge_threads, 1, "pre-axis rows measured the serial merge");
        }
        assert_eq!(loaded.lookup(1 << 18), Some(1 << 14));
        // Saving upgrades the schema in place; the reload is identical.
        let upgraded = dir.join("legacy-tiles-upgraded.tsv");
        loaded.save(&upgraded).unwrap();
        let text = std::fs::read_to_string(&upgraded).unwrap();
        assert!(text.contains(TILE_HEADER), "save writes the 4-field header");
        assert_eq!(TileProfile::load(&upgraded).unwrap(), loaded);
    }

    #[test]
    fn policy_resolves_profile_but_respects_pins() {
        let base = PlanConfig { block: 4096, interleave: 1, ..Default::default() };
        let profile = TuningProfile {
            entries: vec![TunedEntry {
                isa: KernelIsa::Portable,
                ..entry(1024, Dtype::U32, 256, 16, 1)
            }],
        };
        // Tuned policy: profile refines block, interleave and kernel.
        let tuned = PlanPolicy::tuned(base, profile.clone());
        let cfg = tuned.resolve(1024, Dtype::U32);
        assert_eq!((cfg.block, cfg.interleave), (256, 16));
        assert_eq!(cfg.kernel, KernelChoice::Fixed(KernelIsa::Portable));
        assert_eq!(cfg.variant, Variant::Optimized, "profile never flips the variant");
        // No matching class ⇒ base untouched.
        let cfg = tuned.resolve(1024, Dtype::I32);
        assert_eq!((cfg.block, cfg.interleave, cfg.kernel), (4096, 1, KernelChoice::Auto));
        // Pinned fields win over the profile.
        let pinned = PlanPolicy {
            base,
            profile: Some(profile.clone()),
            pin_block: true,
            pin_interleave: false,
            pin_kernel: true,
        };
        let cfg = pinned.resolve(1024, Dtype::U32);
        assert_eq!((cfg.block, cfg.interleave, cfg.kernel), (4096, 16, KernelChoice::Auto));
        // A tuned ISA this host can't execute is skipped, not adopted:
        // the resulting config must still pass plan validation.
        let foreign = PlanPolicy::tuned(
            base,
            TuningProfile {
                entries: vec![TunedEntry {
                    isa: KernelIsa::Avx2,
                    ..entry(1024, Dtype::U32, 256, 16, 1)
                }],
            },
        );
        let cfg = foreign.resolve(1024, Dtype::U32);
        if KernelIsa::Avx2.available() {
            assert_eq!(cfg.kernel, KernelChoice::Fixed(KernelIsa::Avx2));
        } else {
            assert_eq!(cfg.kernel, KernelChoice::Auto);
        }
        assert!(cfg.kernel.validate().is_ok());
        // Fixed policy ignores any profile by construction.
        let fixed = PlanPolicy::fixed(base);
        assert_eq!(fixed.resolve(1024, Dtype::U32), base);
        assert_eq!(PlanPolicy::from(base).resolve(64, Dtype::F32), base);
    }

    #[test]
    fn tune_sweep_measures_and_chooses_per_class() {
        // Structure, not timing: a tiny sweep must measure the full grid,
        // choose one entry per class, and choose it from the grid.
        let req = TuneRequest {
            classes: vec![(64, Dtype::U32), (128, Dtype::F32)],
            blocks: vec![16, 64],
            interleaves: vec![1, 4],
            threads: vec![1],
            // Scalar and Portable are available on every host/build, so
            // the grid size below is deterministic.
            isas: vec![KernelIsa::Scalar, KernelIsa::Portable],
            rows: 4,
            bench: Bench {
                warmup: 0,
                min_iters: 1,
                max_iters: 2,
                target: Duration::from_millis(1),
            },
            seed: 1,
        };
        let out = tune(&req);
        assert_eq!(out.measured.len(), 2 * 2 * 2 * 2);
        assert_eq!(out.profile.entries.len(), 2);
        for (chosen, &(n, dtype)) in out.profile.entries.iter().zip(&req.classes) {
            assert_eq!((chosen.n, chosen.dtype), (n, dtype));
            assert!(req.blocks.contains(&chosen.block));
            assert!(req.interleaves.contains(&chosen.interleave));
            assert!(req.isas.contains(&chosen.isa));
            assert!(chosen.rows_per_sec > 0.0);
            assert!(out
                .measured
                .iter()
                .all(|m| m.n != n || m.dtype != dtype || m.rows_per_sec <= chosen.rows_per_sec));
        }
    }

    #[test]
    fn blocks_clamp_to_class_n() {
        // A candidate block larger than the class's row length must be
        // clamped (Network::launches would clamp it anyway; the sweep
        // dedupes so the grid stays honest).
        let req = TuneRequest {
            classes: vec![(64, Dtype::U32)],
            blocks: vec![64, 4096, 65536],
            interleaves: vec![1],
            threads: vec![1],
            isas: vec![KernelIsa::Scalar],
            rows: 2,
            bench: Bench {
                warmup: 0,
                min_iters: 1,
                max_iters: 1,
                target: Duration::from_millis(1),
            },
            seed: 2,
        };
        let out = tune(&req);
        // 64, 4096→64, 65536→64 dedupe to a single candidate.
        assert_eq!(out.measured.len(), 1);
        assert_eq!(out.measured[0].block, 64);
    }
}
