//! Artifact registry: lazily loaded executors, cached per artifact.
//!
//! In the PJRT design, XLA compilation of one sort artifact takes
//! seconds, so executables are compiled on first use and cached for the
//! life of the process; the native-CPU executor keeps the same
//! load-once/cache discipline (HLO validation is cheap, but the cache is
//! the warm-up contract the service relies on). The registry is `Sync`:
//! the service's worker threads share it behind an `Arc`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::error::Context;
use crate::util::threadpool::ThreadPool;

use super::artifact::{ArtifactKind, ArtifactMeta, Dtype, Manifest};
use super::autotune::PlanPolicy;
use super::executor::SortExecutor;
use crate::sort::network::Variant;

/// Cache key for a compiled executable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Key {
    /// Sort or merge artifact.
    pub kind: ArtifactKind,
    /// Schedule variant.
    pub variant: Variant,
    /// Batch rows.
    pub batch: usize,
    /// Row length.
    pub n: usize,
    /// Key dtype.
    pub dtype: Dtype,
    /// Sort direction.
    pub descending: bool,
}

impl Key {
    /// Key for an artifact's metadata.
    pub fn of(meta: &ArtifactMeta) -> Self {
        Self {
            kind: meta.kind,
            variant: meta.variant,
            batch: meta.batch,
            n: meta.n,
            dtype: meta.dtype,
            descending: meta.descending,
        }
    }
}

/// The registry: manifest plus the per-artifact executor cache.
pub struct Registry {
    manifest: Manifest,
    cache: Mutex<HashMap<Key, Arc<SortExecutor>>>,
    /// Shared row-parallel execution pool handed to every executor this
    /// registry loads; `None` ⇒ executors run serially.
    pool: Option<Arc<ThreadPool>>,
    /// How each artifact's launch-program configuration (variant,
    /// fused-tile block, interleave width) is chosen: a base
    /// [`super::PlanConfig`] optionally refined per `(n, dtype)` size
    /// class by a tuning profile — see [`PlanPolicy::resolve`].
    policy: PlanPolicy,
}

impl Registry {
    /// Open the artifacts directory (must contain `manifest.tsv`);
    /// executors run serially at the default plan configuration.
    pub fn open(dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        Self::open_with_pool(dir, None, PlanPolicy::default())
    }

    /// [`open`](Self::open) with a shared execution pool and a plan
    /// policy: every executor loaded from this registry compiles its
    /// launch program at the policy's per-class resolution (a plain
    /// [`super::PlanConfig`] converts to a fixed policy) and sorts its
    /// `(B, N)` rows in parallel on `pool`. One pool is shared across all
    /// size classes on purpose — the device-host thread dispatches one
    /// batch at a time, so a per-class pool would just multiply idle
    /// threads.
    pub fn open_with_pool(
        dir: impl AsRef<std::path::Path>,
        pool: Option<Arc<ThreadPool>>,
        policy: impl Into<PlanPolicy>,
    ) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        Ok(Self::from_manifest(manifest, pool, policy))
    }

    /// Build a registry over an already-loaded manifest — the seam the
    /// merged fixture+generated discovery uses so the device host and
    /// its caller share one snapshot instead of re-reading (and
    /// possibly re-merging) the directory twice.
    pub fn from_manifest(
        manifest: Manifest,
        pool: Option<Arc<ThreadPool>>,
        policy: impl Into<PlanPolicy>,
    ) -> Self {
        Self {
            manifest,
            cache: Mutex::new(HashMap::new()),
            pool,
            policy: policy.into(),
        }
    }

    /// The manifest the registry serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (loading on first use) the executor for `key`.
    pub fn get(&self, key: Key) -> crate::Result<Arc<SortExecutor>> {
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(e));
        }
        // Load outside the lock: first-touch latency must not serialise
        // unrelated size classes. A racing double-load is benign.
        let meta = self
            .manifest
            .entries
            .iter()
            .find(|a| Key::of(a) == key)
            .with_context(|| format!("no artifact for {key:?} — re-run `python -m compile.aot`"))?
            .clone();
        let path = self.manifest.path_of(&meta);
        // Per-class plan resolution: the tuning profile (when the policy
        // carries one) picks this size class's block/interleave.
        let plan = self.policy.resolve(meta.n, meta.dtype);
        let exe = Arc::new(SortExecutor::compile_with_pool(
            meta,
            &path,
            self.pool.clone(),
            plan,
        )?);
        let mut cache = self.cache.lock().unwrap();
        Ok(Arc::clone(cache.entry(key).or_insert(exe)))
    }

    /// Eagerly load every artifact of `variant` (service warm-up).
    pub fn warm_up(&self, variant: Variant) -> crate::Result<usize> {
        let keys: Vec<Key> = self
            .manifest
            .size_classes(variant)
            .into_iter()
            .map(Key::of)
            .collect();
        for &k in &keys {
            self.get(k)?;
        }
        Ok(keys.len())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Statically verify every plan this registry actually produces:
    /// load each manifest artifact through the normal [`Self::get`] path
    /// (real HLO validation + per-class policy resolution) and run the
    /// network verifier over the compiled [`super::ExecutionPlan`]. An
    /// artifact that refuses to compile becomes a failing finding — it
    /// does **not** abort the audit of the remaining entries.
    pub fn analyze_with(
        &self,
        proofs: &mut crate::analysis::network_check::ProofCache,
        opts: &crate::analysis::VerifyOptions,
    ) -> crate::analysis::Report {
        use crate::analysis::{network_check, Verdict};
        let mut report = crate::analysis::Report::new();
        for meta in &self.manifest.entries {
            match self.get(Key::of(meta)) {
                Ok(exe) => {
                    report.merge(network_check::check_plan(exe.plan(), &meta.name, opts, proofs));
                }
                Err(e) => report.push(
                    "network.compile",
                    &meta.name,
                    Verdict::Fail,
                    format!("artifact did not compile into a plan: {e:#}"),
                ),
            }
        }
        report
    }

    /// [`Self::analyze_with`] with fresh default options and proof cache
    /// — the registry's standalone `analyze` hook.
    pub fn analyze(&self) -> crate::analysis::Report {
        let mut proofs = crate::analysis::network_check::ProofCache::new();
        self.analyze_with(&mut proofs, &crate::analysis::VerifyOptions::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compilation-dependent tests live in rust/tests/ (they need real
    // artifacts); here we only cover the pure parts.

    #[test]
    fn key_of_meta_roundtrip() {
        let meta = ArtifactMeta {
            name: "x".into(),
            kind: ArtifactKind::Sort,
            variant: Variant::Semi,
            batch: 8,
            n: 1024,
            dtype: Dtype::U32,
            descending: false,
            block: 256,
            grid_cells: 16,
            file: "x.hlo.txt".into(),
        };
        let k = Key::of(&meta);
        assert_eq!(k.variant, Variant::Semi);
        assert_eq!(k.batch, 8);
        assert_eq!(k.n, 1024);
        assert!(!k.descending);
    }

    #[test]
    fn open_missing_dir_errors() {
        let err = match Registry::open("/nonexistent-artifacts-dir") {
            Err(e) => e,
            Ok(_) => panic!("open of missing dir must fail"),
        };
        assert!(format!("{err:#}").contains("compile.aot"));
    }
}
