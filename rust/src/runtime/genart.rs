//! Native artifact synthesis: `bitonic-tpu gen-artifacts`.
//!
//! The checked-in fixture under `rust/artifacts/` was produced by the
//! (offline-unavailable) JAX AOT pipeline and tops out at n=64K — the
//! single biggest limiter named in ROADMAP item 1. The executor never
//! needed real XLA though: [`crate::runtime::SortExecutor::compile`]
//! walks a small in-crate HLO *text* format and only checks the module
//! header and the `dtype[batch,n]` shape token. This module renders
//! that exact format natively for any (op, batch, n, dtype, order)
//! grid, so the registry menu reaches n ≥ 1M–16M with zero external
//! tooling, and `bitonic-tpu verify-plans` can statically prove every
//! generated class before it serves traffic.
//!
//! The generated directory is a sibling of the fixture (by default
//! `<artifacts>/generated`, overridable with `BITONIC_GEN_ARTIFACTS`),
//! never checked in, and discovered by the registry through
//! [`Manifest::load_merged`] — fixture rows win on key collisions so a
//! generated grid can never shadow the audited fixture.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use crate::runtime::artifact::{ArtifactKind, Dtype, Manifest};
use crate::sort::network::Variant;

/// Manifest header shared with the fixture and the python mirror
/// (`python/compile/aot.py::MANIFEST_COLUMNS`).
pub const MANIFEST_HEADER: &str =
    "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile";

/// Block-size hint recorded in generated manifest rows (same value the
/// fixture rows carry; the plan policy, not this column, decides the
/// execution geometry).
pub const GEN_BLOCK: usize = 256;

/// One artifact class to synthesize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenSpec {
    pub kind: ArtifactKind,
    pub variant: Variant,
    pub batch: usize,
    pub n: usize,
    pub dtype: Dtype,
    pub descending: bool,
}

impl GenSpec {
    /// Sort-class shorthand (the common case).
    pub fn sort(n: usize, batch: usize, dtype: Dtype, descending: bool) -> Self {
        GenSpec {
            kind: ArtifactKind::Sort,
            variant: Variant::Optimized,
            batch,
            n,
            dtype,
            descending,
        }
    }

    /// Merge-class shorthand (ascending u32, what `sort::hybrid` uses).
    pub fn merge(n: usize, batch: usize) -> Self {
        GenSpec {
            kind: ArtifactKind::Merge,
            variant: Variant::Optimized,
            batch,
            n,
            dtype: Dtype::U32,
            descending: false,
        }
    }

    /// Canonical artifact name, matching the aot namer:
    /// `{kind}_{variant}_b{batch}_n{n}_{dtype}_{asc|desc}`.
    pub fn name(&self) -> String {
        format!(
            "{}_{}_b{}_n{}_{}_{}",
            self.kind.name(),
            self.variant.name(),
            self.batch,
            self.n,
            self.dtype.name(),
            if self.descending { "desc" } else { "asc" },
        )
    }

    /// HLO text file name (`name + ".hlo.txt"`).
    pub fn file(&self) -> String {
        format!("{}.hlo.txt", self.name())
    }

    /// Reject shapes the executor would refuse to compile, before any
    /// file is written.
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(
            self.n.is_power_of_two() && self.n >= 2,
            "gen-artifacts: n={} is not a power of two >= 2",
            self.n
        );
        crate::ensure!(self.batch >= 1, "gen-artifacts: batch must be >= 1");
        Ok(())
    }

    /// Block hint for the manifest row (clamped so tiny classes stay
    /// executable: block must divide into n).
    pub fn block(&self) -> usize {
        GEN_BLOCK.min(self.n)
    }

    /// Grid-cell hint: one cell per block-sized slice of a row.
    pub fn grid_cells(&self) -> usize {
        (self.n / self.block()).max(1)
    }

    /// Render the in-crate HLO text for this class — byte-compatible
    /// with the fixture files the JAX pipeline produced: ascending
    /// classes compare with `direction=LT`, descending with `GT`.
    pub fn hlo_text(&self) -> String {
        let tok = self.dtype.hlo_token();
        let (b, n) = (self.batch, self.n);
        let direction = if self.descending { "GT" } else { "LT" };
        format!(
            "HloModule jit_{name}, entry_computation_layout={{({tok}[{b},{n}]{{1,0}})->(({tok}[{b},{n}]{{1,0}}))}}\n\
             \n\
             %compare.1 (lhs.2: {tok}[], rhs.3: {tok}[]) -> pred[] {{\n\
             \x20 %lhs.2 = {tok}[] parameter(0)\n\
             \x20 %rhs.3 = {tok}[] parameter(1)\n\
             \x20 ROOT %compare.4 = pred[] compare({tok}[] %lhs.2, {tok}[] %rhs.3), direction={direction}\n\
             }}\n\
             \n\
             ENTRY %main.8 (Arg_0.1: {tok}[{b},{n}]) -> ({tok}[{b},{n}]) {{\n\
             \x20 %Arg_0.1 = {tok}[{b},{n}]{{1,0}} parameter(0)\n\
             \x20 %sort.5 = {tok}[{b},{n}]{{1,0}} sort({tok}[{b},{n}]{{1,0}} %Arg_0.1), dimensions={{1}}, to_apply=%compare.1\n\
             \x20 ROOT %tuple.7 = ({tok}[{b},{n}]{{1,0}}) tuple({tok}[{b},{n}]{{1,0}} %sort.5)\n\
             }}\n",
            name = self.name(),
        )
    }

    /// One `manifest.tsv` row (no trailing newline).
    pub fn manifest_row(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
            self.name(),
            self.kind.name(),
            self.variant.name(),
            self.batch,
            self.n,
            self.dtype.name(),
            self.descending as u8,
            self.block(),
            self.grid_cells(),
            self.file(),
        )
    }
}

/// The full offline grid: sorts through the paper's 2^18 peak region up
/// to n=16M, dtype/order coverage at 1M, and the merge ladder the
/// hybrid sorter climbs above the fixture ceiling. ~2–3 minutes of
/// `verify-plans` (sampled proofs; everything here is far above the
/// exhaustive cap, so expect WARNs, not FAILs).
pub fn default_grid() -> Vec<GenSpec> {
    let mut specs = Vec::new();
    // Mega-sort ladder: 128K → 16M, batch 1 (the hierarchical
    // substrate's tile menu comes from the fixture classes below 64K).
    for k in 17..=24 {
        specs.push(GenSpec::sort(1 << k, 1, Dtype::U32, false));
    }
    // dtype / order coverage at the 1M class.
    specs.push(GenSpec::sort(1 << 20, 1, Dtype::U32, true));
    specs.push(GenSpec::sort(1 << 20, 1, Dtype::I32, false));
    specs.push(GenSpec::sort(1 << 20, 1, Dtype::F32, false));
    // Batched mid-size classes (tile sorts for the hierarchical path
    // like to run many rows per dispatch).
    specs.push(GenSpec::sort(1 << 16, 4, Dtype::U32, false));
    specs.push(GenSpec::sort(1 << 17, 2, Dtype::U32, false));
    // Merge ladder continuing the fixture's 128K top end.
    for k in 18..=21 {
        specs.push(GenSpec::merge(1 << k, 1));
    }
    specs
}

/// CI-sized grid: small enough that `gen-artifacts --smoke` +
/// `verify-plans` stays inside a timeout-bounded step, but still
/// crossing both the old 64K fixture ceiling and the 1M line so the
/// above-cap WARN path is exercised for real.
pub fn smoke_grid() -> Vec<GenSpec> {
    vec![
        GenSpec::sort(1 << 18, 1, Dtype::U32, false),
        GenSpec::sort(1 << 18, 1, Dtype::U32, true),
        GenSpec::sort(1 << 18, 1, Dtype::I32, false),
        GenSpec::sort(1 << 18, 1, Dtype::F32, false),
        // The acceptance class: at least one n >= 1M in the grid.
        GenSpec::sort(1 << 20, 1, Dtype::U32, false),
        GenSpec::merge(1 << 19, 1),
    ]
}

/// What [`generate`] did, for CLI reporting and tests.
#[derive(Clone, Debug)]
pub struct GenReport {
    /// Directory the manifest + HLO texts were written into.
    pub dir: PathBuf,
    /// Number of HLO files written this run.
    pub written: usize,
    /// Manifest rows (every spec, deduplicated by name).
    pub rows: usize,
    /// Largest sort n in the grid.
    pub max_sort_n: usize,
}

/// Synthesize `specs` into `dir`: one HLO text per class plus a
/// `manifest.tsv` that references exactly the files written (the
/// `verify-plans` dangling-file audit holds by construction). The
/// directory is created if missing; an existing manifest is replaced
/// wholesale so repeated runs converge instead of accreting.
pub fn generate(dir: &Path, specs: &[GenSpec]) -> crate::Result<GenReport> {
    crate::ensure!(!specs.is_empty(), "gen-artifacts: empty grid");
    std::fs::create_dir_all(dir)
        .map_err(|e| crate::err!("gen-artifacts: creating {}: {e}", dir.display()))?;

    let mut seen: HashSet<String> = HashSet::new();
    let mut rows = Vec::with_capacity(specs.len() + 1);
    rows.push(MANIFEST_HEADER.to_string());
    let mut written = 0usize;
    let mut max_sort_n = 0usize;

    for spec in specs {
        spec.validate()?;
        let name = spec.name();
        if !seen.insert(name.clone()) {
            continue; // same class listed twice — one file, one row
        }
        let path = dir.join(spec.file());
        std::fs::write(&path, spec.hlo_text())
            .map_err(|e| crate::err!("gen-artifacts: writing {}: {e}", path.display()))?;
        written += 1;
        if spec.kind == ArtifactKind::Sort {
            max_sort_n = max_sort_n.max(spec.n);
        }
        rows.push(spec.manifest_row());
    }

    let manifest_path = dir.join("manifest.tsv");
    let text = rows.join("\n") + "\n";
    std::fs::write(&manifest_path, &text)
        .map_err(|e| crate::err!("gen-artifacts: writing {}: {e}", manifest_path.display()))?;

    // Round-trip through the real loader so a drifted renderer fails
    // here, at generation time, not later inside the registry.
    let manifest = Manifest::load(dir)?;
    crate::ensure!(
        manifest.entries.len() == rows.len() - 1,
        "gen-artifacts: wrote {} rows but loader sees {}",
        rows.len() - 1,
        manifest.entries.len()
    );

    Ok(GenReport {
        dir: dir.to_path_buf(),
        written,
        rows: rows.len() - 1,
        max_sort_n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SortExecutor;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bitonic-genart-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn names_and_rows_match_fixture_convention() {
        let s = GenSpec::sort(1 << 20, 1, Dtype::U32, false);
        assert_eq!(s.name(), "sort_optimized_b1_n1048576_uint32_asc");
        assert_eq!(s.file(), "sort_optimized_b1_n1048576_uint32_asc.hlo.txt");
        let row = s.manifest_row();
        assert_eq!(
            row,
            "sort_optimized_b1_n1048576_uint32_asc\tsort\toptimized\t1\t1048576\tuint32\t0\t256\t4096\tsort_optimized_b1_n1048576_uint32_asc.hlo.txt"
        );
        let d = GenSpec::sort(1 << 10, 8, Dtype::I32, true);
        assert_eq!(d.name(), "sort_optimized_b8_n1024_int32_desc");
    }

    #[test]
    fn hlo_text_matches_fixture_format() {
        let s = GenSpec::sort(65536, 1, Dtype::U32, false);
        let text = s.hlo_text();
        // Byte-compatible with the checked-in fixture file of the same
        // class (modulo nothing: this is the exact template).
        assert!(text.starts_with(
            "HloModule jit_sort_optimized_b1_n65536_uint32_asc, entry_computation_layout={(u32[1,65536]{1,0})->((u32[1,65536]{1,0}))}\n"
        ));
        assert!(text.contains("direction=LT"));
        assert!(text.contains(
            "%sort.5 = u32[1,65536]{1,0} sort(u32[1,65536]{1,0} %Arg_0.1), dimensions={1}, to_apply=%compare.1"
        ));
        let desc = GenSpec::sort(1024, 2, Dtype::F32, true);
        let t = desc.hlo_text();
        assert!(t.contains("direction=GT"));
        assert!(t.contains("f32[2,1024]"));
        let i = GenSpec::sort(1024, 1, Dtype::I32, false);
        assert!(i.hlo_text().contains("s32[1,1024]"));
    }

    #[test]
    fn generated_dir_loads_compiles_and_audits_clean() {
        let dir = temp_dir("roundtrip");
        let specs = [
            GenSpec::sort(1 << 17, 1, Dtype::U32, false),
            GenSpec::sort(1 << 10, 4, Dtype::F32, true),
            GenSpec::merge(1 << 12, 2),
        ];
        let report = generate(&dir, &specs).unwrap();
        assert_eq!(report.written, 3);
        assert_eq!(report.rows, 3);
        assert_eq!(report.max_sort_n, 1 << 17);

        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.entries.len(), 3);
        // The artifact auditor (verify-plans pass 3) must be clean: no
        // shape drift, no missing files, no dangling HLO texts.
        let audit = manifest.analyze();
        assert!(!audit.has_fail(), "{}", audit.render_markdown());
        assert_eq!(audit.worst(), crate::analysis::Verdict::Pass);
        // Every generated class compiles in the executor.
        for meta in &manifest.entries {
            let path = manifest.path_of(meta);
            SortExecutor::compile(meta.clone(), &path)
                .unwrap_or_else(|e| panic!("{} does not compile: {e}", meta.name));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn generated_executor_sorts_above_the_fixture_ceiling() {
        let dir = temp_dir("sorts");
        // 128K: the first class above the fixture's 64K ceiling.
        let spec = GenSpec::sort(1 << 17, 1, Dtype::U32, false);
        generate(&dir, &[spec]).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let meta = &manifest.entries[0];
        let exec = SortExecutor::compile(meta.clone(), &manifest.path_of(meta)).unwrap();
        let n = meta.n;
        let mut rows: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let sorted = exec.sort_u32(std::mem::take(&mut rows)).unwrap();
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_specs_collapse_and_grids_are_duplicate_free() {
        let dir = temp_dir("dedup");
        let s = GenSpec::sort(1 << 10, 1, Dtype::U32, false);
        let report = generate(&dir, &[s, s]).unwrap();
        assert_eq!(report.rows, 1);
        let _ = std::fs::remove_dir_all(&dir);

        for grid in [default_grid(), smoke_grid()] {
            let names: HashSet<String> = grid.iter().map(|s| s.name()).collect();
            assert_eq!(names.len(), grid.len());
            for spec in &grid {
                spec.validate().unwrap();
            }
        }
    }

    #[test]
    fn smoke_grid_crosses_the_old_ceiling_and_the_1m_line() {
        let grid = smoke_grid();
        assert!(grid.iter().all(|s| s.kind != ArtifactKind::Sort || s.n > 1 << 16));
        assert!(
            grid.iter().any(|s| s.kind == ArtifactKind::Sort && s.n >= 1 << 20),
            "smoke grid must include the n >= 1M acceptance class"
        );
        let dtypes: HashSet<&str> = grid.iter().map(|s| s.dtype.name()).collect();
        assert!(dtypes.contains("uint32") && dtypes.contains("int32") && dtypes.contains("float32"));
        assert!(grid.iter().any(|s| s.descending) && grid.iter().any(|s| !s.descending));
        assert!(grid.iter().any(|s| s.kind == ArtifactKind::Merge));
    }

    #[test]
    fn invalid_specs_are_rejected_before_any_write() {
        let dir = temp_dir("invalid");
        let bad = GenSpec::sort(1000, 1, Dtype::U32, false); // not pow2
        assert!(generate(&dir, &[bad]).is_err());
        assert!(!dir.join("manifest.tsv").exists());
        let mut zero_batch = GenSpec::sort(1024, 1, Dtype::U32, false);
        zero_batch.batch = 0;
        assert!(zero_batch.validate().is_err());
        assert!(generate(&dir, &[]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
