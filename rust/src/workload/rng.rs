//! Deterministic pseudo-random number generators.
//!
//! The `rand` crate is unavailable offline, so we implement two small,
//! well-studied generators: SplitMix64 (used for seeding and as a fast
//! default) and PCG32 (used where a smaller state / stream separation is
//! convenient). Both are deterministic across platforms, which the test
//! suite and the benchmark harness rely on for reproducible workloads.

/// SplitMix64 — Steele, Lea & Flood (OOPSLA 2014). Passes BigCrush when
/// used as a 64-bit generator; its main role here is fast bulk generation
/// and seeding of other generators.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32 uniformly distributed bits (upper half of `next_u64`).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "next_range: empty range");
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.next_below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// PCG32 (XSH-RR variant) — O'Neill 2014. 64-bit state, 32-bit output,
/// selectable stream. Used by the property-testing framework where many
/// independent, cheap generators are spawned.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6_364_136_223_846_793_005;

    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut g = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        g.next_u32();
        g.state = g.state.wrapping_add(seed);
        g.next_u32();
        g
    }

    /// Next 32 uniformly distributed bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 bits from two 32-bit draws.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` (Lemire, 32-bit variant).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below: bound must be positive");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let lo = m as u32;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values from the public-domain splitmix64.c (Vigna).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut g = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_range_inclusive_bounds() {
        let mut g = SplitMix64::new(9);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2000 {
            let v = g.next_range(5, 8);
            assert!((5..=8).contains(&v));
            saw_lo |= v == 5;
            saw_hi |= v == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn next_range_full_domain() {
        let mut g = SplitMix64::new(3);
        // Must not overflow on the full u64 domain.
        let _ = g.next_range(0, u64::MAX);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = SplitMix64::new(11);
        for _ in 0..1000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move elements");
    }

    #[test]
    fn pcg32_streams_independent() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 1);
    }

    #[test]
    fn pcg32_deterministic() {
        let mut a = Pcg32::new(123, 54);
        let mut b = Pcg32::new(123, 54);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg32_next_below_unbiased_smoke() {
        let mut g = Pcg32::new(0, 0);
        let mut counts = [0u32; 3];
        for _ in 0..3000 {
            counts[g.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "rough uniformity: {counts:?}");
        }
    }
}
