//! Traffic mixes for the serving loadgen: weighted request classes over
//! the existing [`Distribution`] generator.
//!
//! A serving front-end sees *mixed* traffic — different sizes, orders,
//! SLOs, and input distributions at once (the regime where Božidar &
//! Dobravec show algorithm rankings invert, and exactly what per-class
//! autotune profiles assume away). [`TrafficMix`] names that mix;
//! [`TrafficGen`] draws a deterministic request stream from it.
//!
//! Determinism contract (pinned by `rust/tests/service_load.rs`): the
//! stream is a pure function of `(mix, seed)` — same seed, same
//! requests, byte for byte — so latency differences between two loadgen
//! runs are attributable to the server, never the generator.

use std::time::Duration;

use super::generator::{Distribution, Generator};
use super::rng::SplitMix64;

/// One weighted request class in a traffic mix.
#[derive(Clone, Debug)]
pub struct TrafficClass {
    /// Label carried into per-class reports and bench records.
    pub name: &'static str,
    /// Relative draw weight (≥ 1).
    pub weight: u32,
    /// Smallest request length (inclusive, ≥ 1).
    pub min_len: usize,
    /// Largest request length (inclusive).
    pub max_len: usize,
    /// Input distribution of the keys.
    pub dist: Distribution,
    /// Sort order requested.
    pub descending: bool,
    /// SLO budget attached to every request of this class.
    pub slo: Option<Duration>,
}

/// A named set of weighted classes.
#[derive(Clone, Debug)]
pub struct TrafficMix {
    /// The classes, drawn proportionally to their weights.
    pub classes: Vec<TrafficClass>,
}

impl TrafficMix {
    /// The default serving mix: latency-sensitive small sorts dominate,
    /// a medium batch tier rides along, and a trickle of large
    /// descending analytics scans keeps the big classes warm.
    pub fn serving() -> Self {
        Self {
            classes: vec![
                TrafficClass {
                    name: "interactive",
                    weight: 6,
                    min_len: 64,
                    max_len: 1024,
                    dist: Distribution::Uniform,
                    descending: false,
                    slo: Some(Duration::from_millis(50)),
                },
                TrafficClass {
                    name: "batch",
                    weight: 3,
                    min_len: 1024,
                    max_len: 16384,
                    dist: Distribution::DupHeavy,
                    descending: false,
                    slo: Some(Duration::from_millis(250)),
                },
                TrafficClass {
                    name: "analytics",
                    weight: 1,
                    min_len: 16384,
                    max_len: 65536,
                    dist: Distribution::Reverse,
                    descending: true,
                    slo: None,
                },
            ],
        }
    }

    /// A tiny mix for CI smokes: both classes fit the small fixture
    /// artifacts, so a smoke run exercises batching without paying for
    /// 64K-row sorts.
    pub fn smoke() -> Self {
        Self {
            classes: vec![
                TrafficClass {
                    name: "interactive",
                    weight: 4,
                    min_len: 16,
                    max_len: 512,
                    dist: Distribution::Uniform,
                    descending: false,
                    slo: Some(Duration::from_millis(100)),
                },
                TrafficClass {
                    name: "batch",
                    weight: 2,
                    min_len: 512,
                    max_len: 2048,
                    dist: Distribution::DupHeavy,
                    descending: false,
                    slo: Some(Duration::from_millis(500)),
                },
            ],
        }
    }

    /// Look a built-in mix up by CLI name.
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "serving" => Some(Self::serving()),
            "smoke" => Some(Self::smoke()),
            _ => None,
        }
    }

    /// Reject empty or degenerate mixes before a generator is built.
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(!self.classes.is_empty(), "traffic mix has no classes");
        for c in &self.classes {
            crate::ensure!(c.weight >= 1, "class {}: weight must be >= 1", c.name);
            crate::ensure!(c.min_len >= 1, "class {}: min_len must be >= 1", c.name);
            crate::ensure!(
                c.min_len <= c.max_len,
                "class {}: min_len {} > max_len {}",
                c.name,
                c.min_len,
                c.max_len
            );
        }
        Ok(())
    }

    /// Sum of class weights.
    pub fn total_weight(&self) -> u64 {
        self.classes.iter().map(|c| u64::from(c.weight)).sum()
    }

    /// Largest request length any class can draw.
    pub fn max_len(&self) -> usize {
        self.classes.iter().map(|c| c.max_len).max().unwrap_or(0)
    }
}

/// One drawn request (the wire-agnostic shape; the loadgen maps it onto
/// a Sort frame).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrafficRequest {
    /// Sequence number within this generator (0-based).
    pub id: u64,
    /// Index into the mix's `classes`.
    pub class: usize,
    /// The keys to sort.
    pub keys: Vec<u32>,
    /// Sort order.
    pub descending: bool,
    /// SLO budget, from the class.
    pub slo: Option<Duration>,
}

/// Deterministic request stream over a [`TrafficMix`].
pub struct TrafficGen {
    mix: TrafficMix,
    rng: SplitMix64,
    next_id: u64,
}

impl TrafficGen {
    /// Build a generator; panics on an invalid mix (call
    /// [`TrafficMix::validate`] first for a recoverable error).
    pub fn new(mix: TrafficMix, seed: u64) -> Self {
        mix.validate().expect("invalid traffic mix");
        Self {
            mix,
            rng: SplitMix64::new(seed),
            next_id: 0,
        }
    }

    /// The mix this generator draws from.
    pub fn mix(&self) -> &TrafficMix {
        &self.mix
    }

    /// Draw the next request: weighted class pick, uniform length in
    /// the class range, keys from the class distribution.
    pub fn next_request(&mut self) -> TrafficRequest {
        let mut pick = self.rng.next_below(self.mix.total_weight());
        let mut class = self.mix.classes.len() - 1;
        for (i, c) in self.mix.classes.iter().enumerate() {
            if pick < u64::from(c.weight) {
                class = i;
                break;
            }
            pick -= u64::from(c.weight);
        }
        let c = &self.mix.classes[class];
        let span = (c.max_len - c.min_len + 1) as u64;
        let len = c.min_len + self.rng.next_below(span) as usize;
        let keys = Generator::new(self.rng.next_u64()).u32s(len, c.dist);
        let id = self.next_id;
        self.next_id += 1;
        TrafficRequest {
            id,
            class,
            keys,
            descending: c.descending,
            slo: c.slo,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_stream() {
        let mut a = TrafficGen::new(TrafficMix::serving(), 7);
        let mut b = TrafficGen::new(TrafficMix::serving(), 7);
        for _ in 0..200 {
            assert_eq!(a.next_request(), b.next_request());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = TrafficGen::new(TrafficMix::serving(), 1);
        let mut b = TrafficGen::new(TrafficMix::serving(), 2);
        let same = (0..50).filter(|_| a.next_request() == b.next_request()).count();
        assert!(same < 50, "independent seeds produced identical streams");
    }

    #[test]
    fn lengths_respect_class_bounds_and_weights_bias_the_draw() {
        let mix = TrafficMix::serving();
        let mut gen = TrafficGen::new(mix.clone(), 42);
        let mut per_class = vec![0usize; mix.classes.len()];
        for i in 0..600 {
            let r = gen.next_request();
            assert_eq!(r.id, i as u64);
            let c = &mix.classes[r.class];
            assert!(
                (c.min_len..=c.max_len).contains(&r.keys.len()),
                "class {} drew len {}",
                c.name,
                r.keys.len()
            );
            assert_eq!(r.descending, c.descending);
            assert_eq!(r.slo, c.slo);
            per_class[r.class] += 1;
        }
        // 6:3:1 weights: interactive must dominate analytics clearly.
        assert!(per_class[0] > per_class[2] * 2, "weights ignored: {per_class:?}");
        assert!(per_class.iter().all(|&c| c > 0), "a class never drew: {per_class:?}");
    }

    #[test]
    fn builtin_mixes_parse_and_validate() {
        for name in ["serving", "smoke"] {
            let mix = TrafficMix::parse(name).unwrap();
            mix.validate().unwrap();
            assert!(mix.total_weight() >= 1);
            assert!(mix.max_len() >= 1);
        }
        assert!(TrafficMix::parse("nope").is_none());
    }

    #[test]
    fn validate_rejects_degenerate_mixes() {
        assert!(TrafficMix { classes: vec![] }.validate().is_err());
        let mut mix = TrafficMix::smoke();
        mix.classes[0].weight = 0;
        assert!(mix.validate().is_err());
        let mut mix = TrafficMix::smoke();
        mix.classes[0].min_len = 0;
        assert!(mix.validate().is_err());
        let mut mix = TrafficMix::smoke();
        mix.classes[0].min_len = mix.classes[0].max_len + 1;
        assert!(mix.validate().is_err());
    }
}
