//! Workload generation: PRNGs and input distributions.
//!
//! The paper's evaluation uses "32-bit random integer" arrays from 128K to
//! 256M elements. Real workloads are rarely uniform, so the generator also
//! provides the distributions used by the wider sorting literature
//! (sorted, reverse-sorted, nearly-sorted, duplicate-heavy, Gaussian,
//! zero-entropy) for the extended experiments (DESIGN.md E6–E9).
//! [`traffic`] composes those distributions into weighted serving
//! mixes for the loadgen harness.

pub mod datasets;
pub mod generator;
pub mod rng;
pub mod traffic;

pub use generator::{Distribution, Generator};
pub use rng::{Pcg32, SplitMix64};
pub use traffic::{TrafficClass, TrafficGen, TrafficMix, TrafficRequest};
