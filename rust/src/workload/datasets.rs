//! Binary dataset files: persist generated workloads so benchmark runs
//! are replayable byte-for-byte across processes (and so the CLI can
//! pre-generate the paper's 128K–256M inputs once instead of per run).
//!
//! Format (little-endian): 16-byte header `BTSD` + u32 version + u32
//! dtype-tag + u64 element count, then the raw key bytes. A trailing
//! FNV-1a checksum of the payload guards against truncation.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::Context;

const MAGIC: &[u8; 4] = b"BTSD";
const VERSION: u32 = 1;

/// Element type tags in the header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataTag {
    /// 32-bit unsigned keys (the paper's workload).
    U32 = 1,
    /// 64-bit unsigned keys.
    U64 = 2,
    /// 32-bit floats.
    F32 = 3,
}

impl DataTag {
    fn from_u32(v: u32) -> crate::Result<Self> {
        Ok(match v {
            1 => DataTag::U32,
            2 => DataTag::U64,
            3 => DataTag::F32,
            other => crate::bail!("unknown dtype tag {other}"),
        })
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Write `keys` to `path` in the dataset format.
pub fn save_u32(path: impl AsRef<Path>, keys: &[u32]) -> crate::Result<()> {
    save_raw(path, DataTag::U32, keys.len(), bytes_of(keys))
}

/// Write u64 keys.
pub fn save_u64(path: impl AsRef<Path>, keys: &[u64]) -> crate::Result<()> {
    save_raw(path, DataTag::U64, keys.len(), bytes_of(keys))
}

/// Write f32 keys.
pub fn save_f32(path: impl AsRef<Path>, keys: &[f32]) -> crate::Result<()> {
    save_raw(path, DataTag::F32, keys.len(), bytes_of(keys))
}

fn save_raw(path: impl AsRef<Path>, tag: DataTag, count: usize, payload: &[u8]) -> crate::Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tag as u32).to_le_bytes())?;
    f.write_all(&(count as u64).to_le_bytes())?;
    f.write_all(payload)?;
    f.write_all(&fnv1a(payload).to_le_bytes())?;
    Ok(())
}

/// Read a u32 dataset back.
pub fn load_u32(path: impl AsRef<Path>) -> crate::Result<Vec<u32>> {
    let (tag, payload) = load_raw(path)?;
    if tag != DataTag::U32 {
        crate::bail!("dataset holds {tag:?}, not u32");
    }
    Ok(from_bytes(&payload))
}

/// Read a u64 dataset back.
pub fn load_u64(path: impl AsRef<Path>) -> crate::Result<Vec<u64>> {
    let (tag, payload) = load_raw(path)?;
    if tag != DataTag::U64 {
        crate::bail!("dataset holds {tag:?}, not u64");
    }
    Ok(from_bytes(&payload))
}

/// Read an f32 dataset back.
pub fn load_f32(path: impl AsRef<Path>) -> crate::Result<Vec<f32>> {
    let (tag, payload) = load_raw(path)?;
    if tag != DataTag::F32 {
        crate::bail!("dataset holds {tag:?}, not f32");
    }
    Ok(from_bytes(&payload))
}

fn load_raw(path: impl AsRef<Path>) -> crate::Result<(DataTag, Vec<u8>)> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut header = [0u8; 20];
    f.read_exact(&mut header).context("dataset header truncated")?;
    if &header[0..4] != MAGIC {
        crate::bail!("not a BTSD dataset");
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != VERSION {
        crate::bail!("unsupported dataset version {version}");
    }
    let tag = DataTag::from_u32(u32::from_le_bytes(header[8..12].try_into().unwrap()))?;
    let count = u64::from_le_bytes(header[12..20].try_into().unwrap()) as usize;
    let elem = match tag {
        DataTag::U32 | DataTag::F32 => 4,
        DataTag::U64 => 8,
    };
    let mut payload = vec![0u8; count * elem];
    f.read_exact(&mut payload).context("dataset payload truncated")?;
    let mut check = [0u8; 8];
    f.read_exact(&mut check).context("dataset checksum missing")?;
    if u64::from_le_bytes(check) != fnv1a(&payload) {
        crate::bail!("dataset checksum mismatch (corrupt or truncated)");
    }
    Ok((tag, payload))
}

fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), std::mem::size_of_val(xs)) }
}

fn from_bytes<T: Copy>(bytes: &[u8]) -> Vec<T> {
    let n = bytes.len() / std::mem::size_of::<T>();
    let mut out = Vec::<T>::with_capacity(n);
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr().cast::<u8>(), bytes.len());
        out.set_len(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Distribution, Generator};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bitonic-tpu-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn u32_roundtrip() {
        let keys = Generator::new(1).u32s(10_000, Distribution::Uniform);
        let path = tmp("u32.btsd");
        save_u32(&path, &keys).unwrap();
        assert_eq!(load_u32(&path).unwrap(), keys);
    }

    #[test]
    fn u64_and_f32_roundtrip() {
        let mut gen = Generator::new(2);
        let k64 = gen.u64s(1000, Distribution::Uniform);
        let p = tmp("u64.btsd");
        save_u64(&p, &k64).unwrap();
        assert_eq!(load_u64(&p).unwrap(), k64);

        let kf = gen.f32s(1000, Distribution::Uniform);
        let p = tmp("f32.btsd");
        save_f32(&p, &kf).unwrap();
        assert_eq!(load_f32(&p).unwrap(), kf);
    }

    #[test]
    fn wrong_type_rejected() {
        let path = tmp("typed.btsd");
        save_u32(&path, &[1, 2, 3]).unwrap();
        assert!(load_u64(&path).is_err());
        assert!(load_f32(&path).is_err());
    }

    #[test]
    fn truncation_detected() {
        let path = tmp("trunc.btsd");
        save_u32(&path, &(0..1000).collect::<Vec<u32>>()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 12]).unwrap();
        assert!(load_u32(&path).is_err());
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt.btsd");
        save_u32(&path, &(0..1000).collect::<Vec<u32>>()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[100] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_u32(&path).is_err());
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage.btsd");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load_u32(&path).is_err());
    }

    #[test]
    fn empty_dataset_ok() {
        let path = tmp("empty.btsd");
        save_u32(&path, &[]).unwrap();
        assert_eq!(load_u32(&path).unwrap(), Vec::<u32>::new());
    }
}
