//! Input distributions for sorting experiments.

use super::rng::SplitMix64;

/// Key distributions used by the experiments.
///
/// `Uniform` is the paper's workload ("a series of 32-bit random
/// integer"); the others cover the standard adversarial / easy cases used
/// to characterise comparison sorts (quicksort in particular degrades on
/// `Sorted`/`Reverse` without median-of-three, and on `DupHeavy` without
/// three-way partitioning — both of which our implementation handles).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// i.i.d. uniform over the full key domain (the paper's workload).
    Uniform,
    /// Already sorted ascending.
    Sorted,
    /// Sorted descending.
    Reverse,
    /// Sorted, then `swap_fraction`≈5% of random adjacent-ish swaps.
    NearlySorted,
    /// Only `distinct`≈16 distinct values.
    DupHeavy,
    /// Sum of two uniforms (triangular; a cheap Gaussian-ish shape that
    /// stays integer-valued and full-range).
    Gaussianish,
    /// All keys equal.
    Constant,
    /// Organ-pipe: ascending then descending (bitonic by construction —
    /// exercises the "already bitonic" fast path of the network).
    OrganPipe,
}

impl Distribution {
    /// All distributions, for sweep-style tests/benches.
    pub const ALL: [Distribution; 8] = [
        Distribution::Uniform,
        Distribution::Sorted,
        Distribution::Reverse,
        Distribution::NearlySorted,
        Distribution::DupHeavy,
        Distribution::Gaussianish,
        Distribution::Constant,
        Distribution::OrganPipe,
    ];

    /// The survey quartet the benchmark matrix sweeps (after Božidar &
    /// Dobravec's parallel-sort comparison): the paper's i.i.d.-uniform
    /// workload plus the classic easy/adversarial cases — pre-sorted,
    /// reverse-sorted, and few-distinct-keys. One definition so the
    /// matrix bench, its smoke preset and the report stay in lockstep.
    pub const SURVEY: [Distribution; 4] = [
        Distribution::Uniform,
        Distribution::Sorted,
        Distribution::Reverse,
        Distribution::DupHeavy,
    ];

    /// Stable name used in CLI flags and reports.
    pub fn name(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Sorted => "sorted",
            Distribution::Reverse => "reverse",
            Distribution::NearlySorted => "nearly-sorted",
            Distribution::DupHeavy => "dup-heavy",
            Distribution::Gaussianish => "gaussianish",
            Distribution::Constant => "constant",
            Distribution::OrganPipe => "organ-pipe",
        }
    }

    /// Parse a CLI name back into a distribution.
    pub fn parse(s: &str) -> Option<Distribution> {
        Distribution::ALL.iter().copied().find(|d| d.name() == s)
    }
}

/// Deterministic, seedable workload generator.
#[derive(Clone, Debug)]
pub struct Generator {
    rng: SplitMix64,
}

impl Generator {
    /// Create a generator with the given seed.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: SplitMix64::new(seed),
        }
    }

    /// `n` 32-bit unsigned keys with the given distribution.
    pub fn u32s(&mut self, n: usize, dist: Distribution) -> Vec<u32> {
        match dist {
            Distribution::Uniform => (0..n).map(|_| self.rng.next_u32()).collect(),
            Distribution::Sorted => {
                let mut v = self.u32s(n, Distribution::Uniform);
                v.sort_unstable();
                v
            }
            Distribution::Reverse => {
                let mut v = self.u32s(n, Distribution::Sorted);
                v.reverse();
                v
            }
            Distribution::NearlySorted => {
                let mut v = self.u32s(n, Distribution::Sorted);
                let swaps = (n / 20).max(1);
                for _ in 0..swaps {
                    if n < 2 {
                        break;
                    }
                    let i = self.rng.next_below(n as u64) as usize;
                    let j = self.rng.next_below(n as u64) as usize;
                    v.swap(i, j);
                }
                v
            }
            Distribution::DupHeavy => {
                let palette: Vec<u32> = (0..16).map(|_| self.rng.next_u32()).collect();
                (0..n)
                    .map(|_| palette[self.rng.next_below(16) as usize])
                    .collect()
            }
            Distribution::Gaussianish => (0..n)
                .map(|_| {
                    let a = self.rng.next_u32() >> 1;
                    let b = self.rng.next_u32() >> 1;
                    a + b
                })
                .collect(),
            Distribution::Constant => vec![self.rng.next_u32(); n],
            Distribution::OrganPipe => {
                let mut v = self.u32s(n, Distribution::Sorted);
                let half = n / 2;
                v[half..].reverse();
                v
            }
        }
    }

    /// `n` 64-bit unsigned keys (future-work E8: 64-bit integers).
    pub fn u64s(&mut self, n: usize, dist: Distribution) -> Vec<u64> {
        match dist {
            Distribution::Uniform => (0..n).map(|_| self.rng.next_u64()).collect(),
            _ => {
                // Widen the 32-bit shape into 64-bit keys, preserving order
                // structure: high word carries the distribution, low word
                // is uniform noise.
                self.u32s(n, dist)
                    .into_iter()
                    .map(|hi| ((hi as u64) << 32) | self.rng.next_u32() as u64)
                    .collect()
            }
        }
    }

    /// `n` finite 32-bit floats (future-work E8: 32-bit float keys).
    pub fn f32s(&mut self, n: usize, dist: Distribution) -> Vec<f32> {
        match dist {
            Distribution::Uniform => (0..n).map(|_| self.rng.next_f32() * 2e9 - 1e9).collect(),
            _ => self
                .u32s(n, dist)
                .into_iter()
                // Map keys monotonically into floats so the order shape of
                // the distribution is preserved exactly.
                .map(|k| (k as f64 / u32::MAX as f64 * 2e9 - 1e9) as f32)
                .collect(),
        }
    }

    /// `n` finite 64-bit doubles (future-work E8).
    pub fn f64s(&mut self, n: usize, dist: Distribution) -> Vec<f64> {
        match dist {
            Distribution::Uniform => (0..n).map(|_| self.rng.next_f64() * 2e12 - 1e12).collect(),
            _ => self
                .u32s(n, dist)
                .into_iter()
                .map(|k| k as f64 / u32::MAX as f64 * 2e12 - 1e12)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Generator::new(1).u32s(256, Distribution::Uniform);
        let b = Generator::new(1).u32s(256, Distribution::Uniform);
        assert_eq!(a, b);
        let c = Generator::new(2).u32s(256, Distribution::Uniform);
        assert_ne!(a, c);
    }

    #[test]
    fn sorted_is_sorted() {
        let v = Generator::new(3).u32s(512, Distribution::Sorted);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn reverse_is_reverse_sorted() {
        let v = Generator::new(3).u32s(512, Distribution::Reverse);
        assert!(v.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn dup_heavy_has_few_distinct() {
        let mut v = Generator::new(4).u32s(4096, Distribution::DupHeavy);
        v.sort_unstable();
        v.dedup();
        assert!(v.len() <= 16, "found {} distinct values", v.len());
    }

    #[test]
    fn constant_all_equal() {
        let v = Generator::new(5).u32s(128, Distribution::Constant);
        assert!(v.iter().all(|&x| x == v[0]));
    }

    #[test]
    fn organ_pipe_is_bitonic() {
        let v = Generator::new(6).u32s(256, Distribution::OrganPipe);
        let half = v.len() / 2;
        assert!(v[..half].windows(2).all(|w| w[0] <= w[1]));
        assert!(v[half..].windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn nearly_sorted_mostly_sorted() {
        let v = Generator::new(7).u32s(4096, Distribution::NearlySorted);
        let inversions = v.windows(2).filter(|w| w[0] > w[1]).count();
        assert!(inversions < v.len() / 4, "too many inversions: {inversions}");
    }

    #[test]
    fn all_distributions_produce_exact_length() {
        let mut g = Generator::new(8);
        for d in Distribution::ALL {
            assert_eq!(g.u32s(100, d).len(), 100, "{}", d.name());
            assert_eq!(g.u64s(100, d).len(), 100, "{}", d.name());
            assert_eq!(g.f32s(100, d).len(), 100, "{}", d.name());
        }
    }

    #[test]
    fn f32s_finite() {
        let mut g = Generator::new(9);
        for d in Distribution::ALL {
            assert!(g.f32s(256, d).iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn survey_subset_of_all() {
        for d in Distribution::SURVEY {
            assert!(Distribution::ALL.contains(&d), "{}", d.name());
        }
        assert_eq!(Distribution::SURVEY.len(), 4);
        assert_eq!(Distribution::SURVEY[0], Distribution::Uniform);
    }

    #[test]
    fn distribution_name_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::parse(d.name()), Some(d));
        }
        assert_eq!(Distribution::parse("nope"), None);
    }
}
