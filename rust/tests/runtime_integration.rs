//! Integration tests over the real artifacts: the PJRT path must agree
//! bit-for-bit with the CPU substrates.
//!
//! These tests run over the checked-in `rust/artifacts/` fixture (or a
//! real `python -m compile.aot` export); they are skipped with a loud
//! message when no artifacts directory is found at all.

use bitonic_tpu::runtime::{spawn_device_host, Dtype, Key};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{is_sorted, quicksort, same_multiset};
use bitonic_tpu::workload::{Distribution, Generator};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `python -m compile.aot`");
        None
    }
}

#[test]
fn device_sort_matches_cpu_quicksort_all_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let mut gen = Generator::new(0xE2E);
    for variant in Variant::ALL {
        // Smallest ascending u32 artifact of this variant.
        let metas = manifest.size_classes(variant);
        let meta = metas.first().expect("artifact menu empty");
        let (b, n) = (meta.batch, meta.n);
        let rows = gen.u32s(b * n, Distribution::Uniform);
        let sorted = handle.sort_u32(Key::of(meta), rows.clone()).unwrap();
        for r in 0..b {
            let mut want = rows[r * n..(r + 1) * n].to_vec();
            quicksort(&mut want);
            assert_eq!(
                &sorted[r * n..(r + 1) * n],
                &want[..],
                "{variant:?} row {r}"
            );
        }
    }
}

#[test]
fn all_variants_agree_with_each_other() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let mut gen = Generator::new(0xA9);
    // Pick one (batch, n) present for all three variants.
    let basic = manifest.size_classes(Variant::Basic);
    let meta = basic.first().unwrap();
    let rows = gen.u32s(meta.batch * meta.n, Distribution::DupHeavy);
    let mut outputs = Vec::new();
    for variant in Variant::ALL {
        let m = manifest
            .find(variant, meta.batch, meta.n, Dtype::U32, false)
            .expect("artifact matrix incomplete");
        outputs.push(handle.sort_u32(Key::of(m), rows.clone()).unwrap());
    }
    assert_eq!(outputs[0], outputs[1], "basic vs semi");
    assert_eq!(outputs[1], outputs[2], "semi vs optimized");
}

#[test]
fn every_distribution_sorts_on_device() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let metas = manifest.size_classes(Variant::Optimized);
    let meta = metas.first().unwrap();
    let mut gen = Generator::new(3);
    for dist in Distribution::ALL {
        let rows = gen.u32s(meta.batch * meta.n, dist);
        let sorted = handle.sort_u32(Key::of(meta), rows.clone()).unwrap();
        for r in 0..meta.batch {
            let chunk = &sorted[r * meta.n..(r + 1) * meta.n];
            assert!(is_sorted(chunk), "{} row {r}", dist.name());
            assert!(
                same_multiset(&rows[r * meta.n..(r + 1) * meta.n], chunk),
                "{} row {r} lost keys",
                dist.name()
            );
        }
    }
}

#[test]
fn descending_artifact_works() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let Some(meta) = manifest
        .entries
        .iter()
        .find(|m| m.descending && m.dtype == Dtype::U32)
    else {
        eprintln!("SKIP: no descending artifact (quick mode?)");
        return;
    };
    let mut gen = Generator::new(4);
    let rows = gen.u32s(meta.batch * meta.n, Distribution::Uniform);
    let sorted = handle.sort_u32(Key::of(meta), rows).unwrap();
    for r in 0..meta.batch {
        let chunk = &sorted[r * meta.n..(r + 1) * meta.n];
        assert!(bitonic_tpu::sort::is_sorted_desc(chunk), "row {r}");
    }
}

#[test]
fn f32_and_i32_artifacts_work() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let mut gen = Generator::new(5);

    if let Some(meta) = manifest
        .entries
        .iter()
        .find(|m| m.dtype == Dtype::F32 && !m.descending)
    {
        let rows = gen.f32s(meta.batch * meta.n, Distribution::Uniform);
        let sorted = handle.sort_f32(Key::of(meta), rows.clone()).unwrap();
        for r in 0..meta.batch {
            let mut want = rows[r * meta.n..(r + 1) * meta.n].to_vec();
            want.sort_by(f32::total_cmp);
            assert_eq!(&sorted[r * meta.n..(r + 1) * meta.n], &want[..], "f32 row {r}");
        }
    } else {
        eprintln!("SKIP: no f32 artifact");
    }

    if let Some(meta) = manifest
        .entries
        .iter()
        .find(|m| m.dtype == Dtype::I32 && !m.descending)
    {
        let rows: Vec<i32> = gen
            .u32s(meta.batch * meta.n, Distribution::Uniform)
            .into_iter()
            .map(|x| x as i32)
            .collect();
        let sorted = handle.sort_i32(Key::of(meta), rows.clone()).unwrap();
        for r in 0..meta.batch {
            let mut want = rows[r * meta.n..(r + 1) * meta.n].to_vec();
            want.sort_unstable();
            assert_eq!(&sorted[r * meta.n..(r + 1) * meta.n], &want[..], "i32 row {r}");
        }
    } else {
        eprintln!("SKIP: no i32 artifact");
    }
}

#[test]
fn pooled_host_bit_exact_with_serial_host() {
    // The row-parallel device host must agree bit-for-bit with the
    // serial one over the real artifacts.
    let Some(dir) = artifacts_dir() else { return };
    let (serial, manifest) = spawn_device_host(&dir).unwrap();
    let (pooled, _) = bitonic_tpu::runtime::spawn_device_host_with(
        &dir,
        bitonic_tpu::runtime::HostConfig {
            threads: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let mut gen = Generator::new(0x9A11E7);
    for meta in manifest.size_classes(Variant::Optimized) {
        let rows = gen.u32s(meta.batch * meta.n, Distribution::Uniform);
        let a = serial.sort_u32(Key::of(meta), rows.clone()).unwrap();
        let b = pooled.sort_u32(Key::of(meta), rows).unwrap();
        assert_eq!(a, b, "{}", meta.name);
    }
    serial.shutdown();
    pooled.shutdown();
}

#[test]
fn plan_variants_bit_exact_end_to_end() {
    // The fused launch programs (Semi/Optimized, several blocks) must
    // agree bit-for-bit with the step-walk program (Basic) through the
    // whole device path — host thread, registry, executor — over every
    // fixture artifact, while performing fewer full-row passes.
    let Some(dir) = artifacts_dir() else { return };
    use bitonic_tpu::runtime::{spawn_device_host_with, HostConfig, PlanConfig};
    let (walk, manifest) = spawn_device_host_with(
        &dir,
        HostConfig {
            plan: PlanConfig {
                variant: Variant::Basic,
                block: 256,
                interleave: 1,
                ..Default::default()
            }
            .into(),
            ..Default::default()
        },
    )
    .unwrap();
    let mut gen = Generator::new(0xF00D);
    for (variant, block) in [(Variant::Semi, 256), (Variant::Optimized, 256), (Variant::Optimized, 4096)] {
        let (fused, _) = spawn_device_host_with(
            &dir,
            HostConfig {
                threads: 4,
                plan: PlanConfig { variant, block, interleave: 1, ..Default::default() }.into(),
            },
        )
        .unwrap();
        for meta in manifest.size_classes(Variant::Optimized) {
            let rows = gen.u32s(meta.batch * meta.n, Distribution::DupHeavy);
            let a = walk.sort_u32(Key::of(meta), rows.clone()).unwrap();
            let b = fused.sort_u32(Key::of(meta), rows).unwrap();
            assert_eq!(a, b, "{} {variant:?} block={block}", meta.name);
        }
        fused.shutdown();
    }
    walk.shutdown();
}

/// Satellite: the batch-interleaved execution mode must agree bit-for-bit
/// with the scalar row walk through the whole device path — host thread,
/// registry, executor, tile pool — over every fixture artifact, for
/// several interleave widths (fixture batches of 1/2/4/8 rows also
/// exercise the ragged-tile and single-row degenerations).
#[test]
fn interleaved_host_bit_exact_with_scalar_host() {
    let Some(dir) = artifacts_dir() else { return };
    use bitonic_tpu::runtime::{spawn_device_host_with, HostConfig, PlanConfig};
    let scalar_plan = PlanConfig { block: 4096, interleave: 1, ..Default::default() };
    let (scalar, manifest) = spawn_device_host_with(
        &dir,
        HostConfig {
            plan: scalar_plan.into(),
            ..Default::default()
        },
    )
    .unwrap();
    let mut gen = Generator::new(0x1EAF);
    for r in [4usize, 16] {
        let (interleaved, _) = spawn_device_host_with(
            &dir,
            HostConfig {
                threads: 4,
                plan: PlanConfig { interleave: r, ..scalar_plan }.into(),
            },
        )
        .unwrap();
        for meta in manifest.size_classes(Variant::Optimized) {
            let rows = gen.u32s(meta.batch * meta.n, Distribution::DupHeavy);
            let a = scalar.sort_u32(Key::of(meta), rows.clone()).unwrap();
            let b = interleaved.sort_u32(Key::of(meta), rows).unwrap();
            assert_eq!(a, b, "{} R={r}", meta.name);
        }
        interleaved.shutdown();
    }
    scalar.shutdown();
}

/// The registry consults a tuning profile per (n, dtype) class: an
/// executor loaded under a tuned policy must carry the profile's
/// block/interleave for its class, while a pinned field keeps the base
/// value.
#[test]
fn registry_resolves_plan_from_tuning_profile() {
    let Some(dir) = artifacts_dir() else { return };
    use bitonic_tpu::runtime::{
        PlanConfig, PlanPolicy, Registry, TunedEntry, TuningProfile,
    };
    let (serial, manifest) = spawn_device_host(&dir).unwrap();
    serial.shutdown();
    let meta = manifest.size_classes(Variant::Optimized)[0].clone();
    let profile = TuningProfile {
        entries: vec![TunedEntry {
            n: meta.n,
            dtype: meta.dtype,
            variant: Variant::Optimized,
            block: 64,
            interleave: 2,
            threads: 1,
            rows_per_sec: 1.0,
        }],
    };
    let base = PlanConfig::default();
    let registry =
        Registry::open_with_pool(&dir, None, PlanPolicy::tuned(base, profile.clone())).unwrap();
    let exe = registry.get(Key::of(&meta)).unwrap();
    assert_eq!(exe.plan().config().block, 64, "profile block must be consulted");
    assert_eq!(exe.plan().config().interleave, 2);
    // Same profile, but the operator pinned --plan-block: base wins there.
    let pinned = PlanPolicy {
        base,
        profile: Some(profile),
        pin_block: true,
        pin_interleave: false,
    };
    let registry = Registry::open_with_pool(&dir, None, pinned).unwrap();
    let exe = registry.get(Key::of(&meta)).unwrap();
    assert_eq!(exe.plan().config().block, base.block, "pinned block must win");
    assert_eq!(exe.plan().config().interleave, 2);
    // And the tuned executor still sorts correctly.
    let mut gen = Generator::new(0x7E57ED);
    let rows = gen.u32s(meta.batch * meta.n, Distribution::DupHeavy);
    let sorted = exe.sort_u32(rows.clone()).unwrap();
    for r in 0..meta.batch {
        let mut want = rows[r * meta.n..(r + 1) * meta.n].to_vec();
        want.sort_unstable();
        assert_eq!(&sorted[r * meta.n..(r + 1) * meta.n], &want[..], "row {r}");
    }
}

#[test]
fn wrong_buffer_size_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let metas = manifest.size_classes(Variant::Optimized);
    let meta = metas.first().unwrap();
    let err = handle
        .sort_u32(Key::of(meta), vec![1, 2, 3])
        .unwrap_err();
    assert!(format!("{err:#}").contains("bytes"));
}

#[test]
fn missing_artifact_errors_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let meta = manifest.entries.first().unwrap();
    let mut key = Key::of(meta);
    key.n = 1 << 27; // certainly not exported
    let err = handle.sort_u32(key, vec![0; 4]).unwrap_err();
    assert!(format!("{err:#}").contains("no artifact"));
}

#[test]
fn padding_contract_device_vs_cpu() {
    // MAX-padding + truncate on the device equals CPU sort of the prefix —
    // the contract the coordinator router relies on.
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let metas = manifest.size_classes(Variant::Optimized);
    let meta = metas.first().unwrap();
    let mut gen = Generator::new(6);
    let real_len = meta.n - meta.n / 3;
    let mut rows = vec![u32::MAX; meta.batch * meta.n];
    let mut wants = Vec::new();
    for r in 0..meta.batch {
        let data = gen.u32s(real_len, Distribution::Uniform);
        rows[r * meta.n..r * meta.n + real_len].copy_from_slice(&data);
        let mut want = data;
        quicksort(&mut want);
        wants.push(want);
    }
    let sorted = handle.sort_u32(Key::of(meta), rows).unwrap();
    for r in 0..meta.batch {
        assert_eq!(&sorted[r * meta.n..r * meta.n + real_len], &wants[r][..]);
    }
}
