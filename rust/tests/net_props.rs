//! Protocol property tests for `coordinator::net`: randomized codec
//! round-trips, the pinned golden byte vectors (mirrored byte-for-byte
//! by `python/tests/test_net.py`), a malformed-frame table, truncation
//! sweeps, seeded garbage fuzzing, and live-socket behaviors a unit
//! test cannot reach — garbage on the wire, oversize length prefixes,
//! and client-sent server ops against a real `NetServer`.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use bitonic_tpu::coordinator::net::{
    frame_cap, read_event_blocking, ErrorCode, Frame, FrameReader, NetClient, NetServer,
    NetServerConfig, ReadEvent, SortReply, WireError, DEFAULT_MAX_KEYS, MAGIC, MAX_ERROR_MSG,
    VERSION,
};
use bitonic_tpu::coordinator::{BatchSorter, Service, ServiceConfig};
use bitonic_tpu::sort::bitonic_sort;
use bitonic_tpu::workload::rng::{Pcg32, SplitMix64};

// ---------------------------------------------------------------------
// Test scaffolding: a CPU mock service behind a real TCP server.
// ---------------------------------------------------------------------

struct Mock {
    batch: usize,
    n: usize,
}

impl BatchSorter for Mock {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
        for r in rows.chunks_mut(self.n) {
            bitonic_sort(r);
        }
        Ok(rows)
    }
}

fn serve(config: NetServerConfig) -> (NetServer, Arc<Service>) {
    let svc = Service::new(
        vec![
            Arc::new(Mock { batch: 4, n: 64 }) as Arc<dyn BatchSorter>,
            Arc::new(Mock { batch: 2, n: 1024 }) as Arc<dyn BatchSorter>,
        ],
        ServiceConfig::default(),
    );
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", config).unwrap();
    (server, svc)
}

fn teardown(mut server: NetServer, svc: Arc<Service>) {
    server.request_shutdown();
    server.shutdown();
    svc.shutdown();
}

// ---------------------------------------------------------------------
// Randomized round-trips.
// ---------------------------------------------------------------------

fn random_frame(rng: &mut Pcg32) -> Frame {
    let keys = |rng: &mut Pcg32| -> Vec<u32> {
        let len = rng.next_below(64) as usize;
        (0..len).map(|_| rng.next_u32()).collect()
    };
    let id = u64::from(rng.next_u32()) << 32 | u64::from(rng.next_u32());
    match rng.next_below(6) {
        0 => Frame::Sort {
            id,
            descending: rng.next_below(2) == 1,
            slo_us: rng.next_u32(),
            keys: keys(rng),
        },
        1 => Frame::Sorted {
            id,
            cpu_path: rng.next_below(2) == 1,
            latency_us: rng.next_u32(),
            occupancy: rng.next_u32(),
            keys: keys(rng),
        },
        2 => {
            let len = rng.next_below(48) as usize;
            let message: String = (0..len)
                .map(|_| char::from(b'a' + (rng.next_below(26) as u8)))
                .collect();
            Frame::Error {
                code: ErrorCode::from_u8(1 + rng.next_below(5) as u8).unwrap(),
                id,
                message,
            }
        }
        3 => Frame::Ping { token: id },
        4 => Frame::Pong { token: id },
        _ => Frame::Shutdown { token: id },
    }
}

#[test]
fn randomized_frames_round_trip() {
    let mut rng = Pcg32::new(0x4E45_5450, 11);
    for _ in 0..500 {
        let frame = random_frame(&mut rng);
        let body = frame.encode_body();
        let back = Frame::decode_body(&body, DEFAULT_MAX_KEYS).unwrap();
        assert_eq!(frame, back);
        // The outer framing layer agrees with the body encoder.
        let encoded = frame.encode();
        assert_eq!(&encoded[4..], &body[..]);
        assert_eq!(
            u32::from_le_bytes(encoded[..4].try_into().unwrap()) as usize,
            body.len()
        );
    }
}

// ---------------------------------------------------------------------
// Golden vectors — pinned in wire.rs unit tests AND in
// python/tests/test_net.py. All three implementations must agree.
// ---------------------------------------------------------------------

#[test]
fn golden_ping_frame_bytes() {
    let encoded = Frame::Ping { token: 0x0102_0304_0506_0708 }.encode();
    assert_eq!(
        encoded,
        vec![
            0x0e, 0x00, 0x00, 0x00, // length prefix = 14
            0x42, 0x54, 0x53, 0x50, // "BTSP"
            0x01, 0x04, // version, op=Ping
            0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // token LE
        ]
    );
}

#[test]
fn golden_sort_frame_bytes() {
    let encoded = Frame::Sort { id: 7, descending: false, slo_us: 0, keys: vec![1, 2] }.encode();
    assert_eq!(
        encoded,
        vec![
            0x20, 0x00, 0x00, 0x00, // length prefix = 32
            0x42, 0x54, 0x53, 0x50, 0x01, 0x01, // header, op=Sort
            0x00, 0x00, // dtype=u32, order=ascending
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id
            0x00, 0x00, 0x00, 0x00, // slo_us
            0x02, 0x00, 0x00, 0x00, // n
            0x01, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00, // keys
        ]
    );
}

#[test]
fn golden_error_frame_bytes() {
    let encoded =
        Frame::Error { code: ErrorCode::Shed, id: 9, message: "shed".into() }.encode();
    assert_eq!(
        encoded,
        vec![
            0x14, 0x00, 0x00, 0x00, // length prefix = 20
            0x42, 0x54, 0x53, 0x50, 0x01, 0x03, // header, op=Error
            0x04, 0x00, // code=Shed, reserved
            0x09, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id
            0x73, 0x68, 0x65, 0x64, // "shed"
        ]
    );
}

// ---------------------------------------------------------------------
// Malformed frames, by kind.
// ---------------------------------------------------------------------

fn expect_kind(body: &[u8], kind: &str) {
    match Frame::decode_body(body, DEFAULT_MAX_KEYS) {
        Err(e) => assert_eq!(e.kind(), kind, "body {body:02x?}"),
        Ok(f) => panic!("expected {kind}, decoded {f:?}"),
    }
}

#[test]
fn malformed_bodies_fail_with_the_right_kind() {
    let sort = Frame::Sort { id: 1, descending: false, slo_us: 0, keys: vec![5] }.encode_body();

    // Header damage.
    let mut bad = sort.clone();
    bad[0] = b'X';
    expect_kind(&bad, "bad-magic");
    let mut bad = sort.clone();
    bad[4] = 99;
    expect_kind(&bad, "bad-version");
    let mut bad = sort.clone();
    bad[5] = 42;
    expect_kind(&bad, "bad-op");

    // Field damage on Sort.
    let mut bad = sort.clone();
    bad[6] = 7; // dtype
    expect_kind(&bad, "bad-dtype");
    let mut bad = sort.clone();
    bad[7] = 2; // order
    expect_kind(&bad, "bad-order");

    // Length damage.
    expect_kind(&sort[..sort.len() - 1], "truncated");
    let mut bad = sort.clone();
    bad.push(0);
    expect_kind(&bad, "trailing");

    // n field larger than the payload actually carries.
    let mut bad = sort.clone();
    bad[20] = 2; // claims 2 keys, carries 1
    expect_kind(&bad, "truncated");

    // Sorted-specific: reserved byte and path flag.
    let sorted = Frame::Sorted { id: 1, cpu_path: false, latency_us: 1, occupancy: 1, keys: vec![] }
        .encode_body();
    let mut bad = sorted.clone();
    bad[6] = 3; // path
    expect_kind(&bad, "bad-path");
    let mut bad = sorted;
    bad[7] = 1; // reserved
    expect_kind(&bad, "bad-reserved");

    // Error-specific: unknown code, non-UTF-8 message.
    let error = Frame::Error { code: ErrorCode::Internal, id: 1, message: "x".into() }
        .encode_body();
    let mut bad = error.clone();
    bad[6] = 0;
    expect_kind(&bad, "bad-code");
    let mut bad = error;
    bad[16] = 0xFF;
    expect_kind(&bad, "bad-utf8");

    // Oversize n against a small cap.
    let big = Frame::Sort { id: 1, descending: false, slo_us: 0, keys: vec![0; 9] }.encode_body();
    match Frame::decode_body(&big, 8) {
        Err(WireError::Oversize { got, cap }) => {
            assert_eq!((got, cap), (9, 8));
        }
        other => panic!("expected oversize, got {other:?}"),
    }
}

#[test]
fn every_truncation_of_every_frame_type_is_rejected() {
    let frames = vec![
        Frame::Sort { id: 3, descending: true, slo_us: 9, keys: vec![1, 2, 3] },
        Frame::Sorted { id: 3, cpu_path: true, latency_us: 5, occupancy: 2, keys: vec![7] },
        Frame::Ping { token: 1 },
        Frame::Pong { token: 2 },
        Frame::Shutdown { token: 3 },
    ];
    for frame in frames {
        let body = frame.encode_body();
        for cut in 0..body.len() {
            assert!(
                Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS).is_err(),
                "{frame:?} decoded from a {cut}-byte prefix"
            );
        }
    }
    // Error is the one variable-tail op without its own length field: a
    // truncated body is a valid frame with a shorter message (the outer
    // length prefix delimits it on the wire), so only cuts into the
    // 16-byte fixed part must fail.
    let body = Frame::Error { code: ErrorCode::Oversize, id: 3, message: "too big".into() }
        .encode_body();
    for cut in 0..16 {
        assert!(
            Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS).is_err(),
            "Error decoded from a {cut}-byte prefix"
        );
    }
    for cut in 16..=body.len() {
        assert!(
            matches!(
                Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS),
                Ok(Frame::Error { .. })
            ),
            "Error body with a {cut}-byte message tail failed to decode"
        );
    }
}

#[test]
fn garbage_bodies_never_panic_and_never_alias_valid_frames() {
    let mut rng = SplitMix64::new(0xB170_F422);
    for round in 0..2000 {
        let len = (rng.next_u64() % 256) as usize;
        let mut body: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // Half the rounds get a valid header so decoding reaches the
        // per-op field validation instead of dying on the magic check.
        if round % 2 == 0 && body.len() >= 6 {
            body[..4].copy_from_slice(&MAGIC);
            body[4] = VERSION;
            body[5] = 1 + (rng.next_u64() % 6) as u8;
        }
        let _ = Frame::decode_body(&body, DEFAULT_MAX_KEYS);
    }
}

// ---------------------------------------------------------------------
// FrameReader: incremental delivery.
// ---------------------------------------------------------------------

/// Yields one byte per `read`, with a `WouldBlock` tick between bytes —
/// the worst-case fragmentation a non-blocking socket can produce.
struct Dribble {
    bytes: Vec<u8>,
    pos: usize,
    tick: bool,
}

impl std::io::Read for Dribble {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.tick {
            self.tick = false;
            return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
        }
        self.tick = true;
        if self.pos >= self.bytes.len() {
            return Ok(0); // clean EOF at a frame boundary
        }
        buf[0] = self.bytes[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

#[test]
fn frame_reader_reassembles_byte_dribbled_frames() {
    let frames = vec![
        Frame::Sort { id: 1, descending: false, slo_us: 100, keys: vec![3, 1, 2] },
        Frame::Ping { token: 77 },
        Frame::Error { code: ErrorCode::Malformed, id: 0, message: "nope".into() },
    ];
    let mut bytes = Vec::new();
    for f in &frames {
        bytes.extend_from_slice(&f.encode());
    }
    let mut src = Dribble { bytes, pos: 0, tick: false };
    let mut reader = FrameReader::new();
    let mut got = Vec::new();
    loop {
        match reader.poll(&mut src, DEFAULT_MAX_KEYS).unwrap() {
            None => continue, // WouldBlock tick
            Some(ReadEvent::Frame(f)) => got.push(f),
            Some(ReadEvent::Eof) => break,
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(got, frames);
    assert!(!reader.has_partial());
}

#[test]
fn frame_reader_flags_oversize_prefix_and_midframe_eof() {
    // Length prefix past the frame cap → protocol event, not an alloc.
    let cap = frame_cap(DEFAULT_MAX_KEYS);
    let mut bytes = (u32::try_from(cap).unwrap() + 1).to_le_bytes().to_vec();
    bytes.extend_from_slice(&[0; 8]);
    let mut reader = FrameReader::new();
    match reader
        .poll(&mut std::io::Cursor::new(bytes), DEFAULT_MAX_KEYS)
        .unwrap()
    {
        Some(ReadEvent::Protocol(e)) => assert_eq!(e.kind(), "oversize"),
        other => panic!("expected protocol event, got {other:?}"),
    }

    // EOF in the middle of a frame → Disconnected, not Eof.
    let encoded = Frame::Ping { token: 9 }.encode();
    let mut reader = FrameReader::new();
    let mut cur = std::io::Cursor::new(encoded[..encoded.len() - 3].to_vec());
    loop {
        match reader.poll(&mut cur, DEFAULT_MAX_KEYS).unwrap() {
            Some(ReadEvent::Disconnected) => break,
            Some(ReadEvent::Frame(f)) => panic!("decoded {f:?} from a truncated stream"),
            Some(ReadEvent::Eof) => panic!("mid-frame EOF reported as clean"),
            _ => continue,
        }
    }
}

#[test]
fn error_messages_clamp_to_the_wire_limit_on_a_char_boundary() {
    // 'é' is 2 bytes; an odd limit forces the clamp off a boundary.
    let long: String = "é".repeat(MAX_ERROR_MSG);
    let body = Frame::Error { code: ErrorCode::Internal, id: 1, message: long }.encode_body();
    match Frame::decode_body(&body, DEFAULT_MAX_KEYS).unwrap() {
        Frame::Error { message, .. } => {
            assert!(message.len() <= MAX_ERROR_MSG);
            assert!(!message.is_empty());
            assert!(message.chars().all(|c| c == 'é'));
        }
        other => panic!("{other:?}"),
    }
}

// ---------------------------------------------------------------------
// Live-server protocol behaviors.
// ---------------------------------------------------------------------

#[test]
fn live_server_sorts_both_directions_over_the_wire() {
    let (server, svc) = serve(NetServerConfig::default());
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let keys = vec![9u32, 1, 5, 3, 7];
    match client.sort(1, keys.clone(), false, None).unwrap() {
        SortReply::Sorted { keys: out, .. } => assert_eq!(out, vec![1, 3, 5, 7, 9]),
        other => panic!("{other:?}"),
    }
    match client
        .sort(2, keys, true, Some(Duration::from_secs(60)))
        .unwrap()
    {
        SortReply::Sorted { keys: out, .. } => assert_eq!(out, vec![9, 7, 5, 3, 1]),
        other => panic!("{other:?}"),
    }
    client.ping(0xDEAD).unwrap();
    assert_eq!(server.stats().frames_in.get(), 3);
    teardown(server, svc);
}

#[test]
fn live_server_answers_garbage_with_an_error_frame_then_closes() {
    let (server, svc) = serve(NetServerConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    // A plausible length prefix followed by garbage that fails the magic
    // check once the body arrives.
    let mut bytes = 14u32.to_le_bytes().to_vec();
    bytes.extend_from_slice(b"XXXXxxxxxxxxxx");
    stream.write_all(&bytes).unwrap();
    match read_event_blocking(&mut stream, DEFAULT_MAX_KEYS).unwrap() {
        ReadEvent::Frame(Frame::Error { code, id, .. }) => {
            assert_eq!(code, ErrorCode::Malformed);
            assert_eq!(id, 0);
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    // The server closes after a protocol error; the next read is EOF.
    match read_event_blocking(&mut stream, DEFAULT_MAX_KEYS).unwrap() {
        ReadEvent::Eof => {}
        other => panic!("expected EOF after protocol error, got {other:?}"),
    }
    assert!(server.stats().protocol_errors.get() >= 1);
    teardown(server, svc);
}

#[test]
fn live_server_rejects_oversize_length_prefix() {
    let (server, svc) = serve(NetServerConfig { max_keys: 256, ..NetServerConfig::default() });
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let huge = u32::try_from(frame_cap(256)).unwrap() + 1;
    stream.write_all(&huge.to_le_bytes()).unwrap();
    match read_event_blocking(&mut stream, DEFAULT_MAX_KEYS).unwrap() {
        ReadEvent::Frame(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Oversize),
        other => panic!("expected an oversize error frame, got {other:?}"),
    }
    teardown(server, svc);
}

#[test]
fn live_server_rejects_oversize_sort_but_keeps_smaller_requests_working() {
    let (server, svc) = serve(NetServerConfig { max_keys: 128, ..NetServerConfig::default() });
    // Client caps must admit the reply; only the server's cap is small.
    let mut client =
        NetClient::connect_with(server.local_addr(), Duration::from_secs(10), DEFAULT_MAX_KEYS)
            .unwrap();
    match client.sort(5, vec![0u32; 200], false, None).unwrap() {
        SortReply::Rejected { code, .. } => assert_eq!(code, ErrorCode::Oversize),
        other => panic!("expected oversize rejection, got {other:?}"),
    }
    teardown(server, svc);
}

#[test]
fn live_server_flags_client_sent_server_ops_but_keeps_the_connection() {
    let (server, svc) = serve(NetServerConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let bogus = Frame::Sorted { id: 8, cpu_path: false, latency_us: 1, occupancy: 1, keys: vec![] };
    stream.write_all(&bogus.encode()).unwrap();
    match read_event_blocking(&mut stream, DEFAULT_MAX_KEYS).unwrap() {
        ReadEvent::Frame(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Malformed),
        other => panic!("expected malformed error, got {other:?}"),
    }
    // Connection must survive: a ping still round-trips on it.
    stream.write_all(&Frame::Ping { token: 31 }.encode()).unwrap();
    match read_event_blocking(&mut stream, DEFAULT_MAX_KEYS).unwrap() {
        ReadEvent::Frame(Frame::Pong { token }) => assert_eq!(token, 31),
        other => panic!("expected pong, got {other:?}"),
    }
    teardown(server, svc);
}
