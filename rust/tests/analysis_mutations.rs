//! Mutation suite for the static plan verifier (`analysis`): seeded
//! corruptions of launch programs, schedules and artifacts, each of
//! which the verifier must **reject** — the teeth behind the PASS
//! verdicts CI gates on. Every verdict asserted here is cross-derived
//! by the jax-free python port (`python/tests/test_static_check.py`),
//! which runs the same proof engines (same sampling family, same PCG32
//! streams) in a second implementation.
//!
//! Also pins the end-to-end behaviors: `verify_plans` over the
//! checked-in fixture is clean, over a corrupted manifest it fails
//! without panicking, and a stale `autotune.tsv` row degrades to a
//! WARN (regression: it used to be treated as load-fatal).

use std::path::PathBuf;

use bitonic_tpu::analysis::disjoint::{
    check_bucket_partition, check_bucket_plan, check_intervals, check_tile_dispatch,
};
use bitonic_tpu::analysis::network_check::{
    canonical_steps, check_merge_steps, check_sort_steps, Outcome,
};
use bitonic_tpu::analysis::{verify_plans, Verdict, VerifyOptions};
use bitonic_tpu::runtime::ArtifactKind;
use bitonic_tpu::sort::bitonic_parallel::IntervalOp;
use bitonic_tpu::sort::network::Step;

fn opts() -> VerifyOptions {
    VerifyOptions { exhaustive_cap: 1024, samples: 96, threads_menu: vec![2, 4] }
}

fn assert_refuted(outcome: Outcome, what: &str) {
    match outcome {
        Outcome::Refuted { detail } => {
            assert!(detail.contains("0-1") || detail.contains("input"), "{what}: {detail}");
        }
        other => panic!("{what} was not refuted: {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Mutant 1: dropped final step, small n — exhaustive brute force.
// ---------------------------------------------------------------------

#[test]
fn mutant_dropped_step_small_is_refuted() {
    let mut steps = canonical_steps(ArtifactKind::Sort, 16);
    steps.pop();
    assert_refuted(check_sort_steps(16, &steps, &opts()), "dropped step n=16");
}

// ---------------------------------------------------------------------
// Mutant 2: dropped final step, large n — the *sampled* fallback path
// must still find a witness (validated against the python port).
// ---------------------------------------------------------------------

#[test]
fn mutant_dropped_step_large_is_refuted_by_sampling() {
    let mut steps = canonical_steps(ArtifactKind::Sort, 1024);
    steps.pop();
    assert_refuted(check_sort_steps(1024, &steps, &opts()), "dropped step n=1024");
}

// ---------------------------------------------------------------------
// Mutant 3: flipped direction. The direction bit is `i & phase_len`, so
// the corruption must hit an *earlier* phase — in the final phase
// `i & n == 0` for every `i < n` and a phase_len bump is a no-op.
// ---------------------------------------------------------------------

#[test]
fn mutant_flipped_direction_is_refuted() {
    let mut steps = canonical_steps(ArtifactKind::Sort, 16);
    let i = steps
        .iter()
        .position(|s| *s == Step { phase_len: 4, stride: 2 })
        .expect("canonical n=16 schedule has step (4,2)");
    steps[i] = Step { phase_len: 8, stride: 2 };
    assert_refuted(check_sort_steps(16, &steps, &opts()), "flipped direction n=16");
}

// ---------------------------------------------------------------------
// Mutant 4: off-by-one stride (4 -> 3): a non-power-of-two stride, so
// the refutation exercises the generic per-pair kernel path.
// ---------------------------------------------------------------------

#[test]
fn mutant_off_by_one_stride_is_refuted() {
    let mut steps = canonical_steps(ArtifactKind::Sort, 16);
    let i = steps
        .iter()
        .position(|s| *s == Step { phase_len: 8, stride: 4 })
        .expect("canonical n=16 schedule has step (8,4)");
    steps[i] = Step { phase_len: 8, stride: 3 };
    assert_refuted(check_sort_steps(16, &steps, &opts()), "off-by-one stride n=16");
}

// ---------------------------------------------------------------------
// Mutant 5: overlapping quad / racy barrier interval — two unpaired
// global strides in ONE interval, exactly the race the §4.2 register
// pairing exists to prevent. The disjointness checker must name the
// two colliding workers.
// ---------------------------------------------------------------------

#[test]
fn mutant_racy_interval_is_rejected() {
    let racy = vec![vec![
        IntervalOp::GlobalLows { phase_len: 16, stride: 8 },
        IntervalOp::GlobalLows { phase_len: 16, stride: 4 },
    ]];
    let err = check_intervals(16, 4, &racy).unwrap_err();
    assert!(err.contains("workers"), "{err}");
}

// ---------------------------------------------------------------------
// Mutants 5b–5e: corrupted splitter bucket plans. `MergePlan.cuts` is a
// public field exactly so this suite can hand the checker plans the
// planner would never emit; each corruption must come back as a finding
// (checked arithmetic — never a panic), while the honest plan passes.
// ---------------------------------------------------------------------

fn bucket_fixture() -> (Vec<Vec<u32>>, bitonic_tpu::sort::MergePlan) {
    let runs: Vec<Vec<u32>> = vec![
        (0..40).map(|i| i * 3).collect(),
        (0..40).map(|i| i * 3 + 1).collect(),
        (0..24).map(|i| i * 5).collect(),
    ];
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    let plan = bitonic_tpu::sort::plan_partition(&views, 4);
    (runs, plan)
}

#[test]
fn honest_bucket_plan_is_accepted() {
    let (runs, plan) = bucket_fixture();
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    let stats = check_bucket_plan(&views, &plan).expect("planner output must verify");
    assert_eq!(stats.total, 40 + 40 + 24);
    assert!(stats.parts >= 2);
    // The plan-then-check wrapper agrees with checking the plan directly.
    assert!(check_bucket_partition(&views, 4).is_ok());
}

#[test]
fn mutant_non_monotone_bucket_plan_is_rejected() {
    let (runs, mut plan) = bucket_fixture();
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    plan.cuts[1] = views.iter().map(|r| r.len()).collect();
    plan.cuts[2] = vec![0; views.len()];
    let err = check_bucket_plan(&views, &plan).unwrap_err();
    assert!(err.contains("decrease"), "{err}");
}

#[test]
fn mutant_short_bucket_plan_is_rejected() {
    // Final row stops one key short of run 0: that key belongs to no
    // bucket, so the output carving would leave a MAX-pad hole.
    let (runs, mut plan) = bucket_fixture();
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    let parts = plan.cuts.len() - 1;
    plan.cuts[parts][0] -= 1;
    let err = check_bucket_plan(&views, &plan).unwrap_err();
    assert!(err.contains("final cut row"), "{err}");
}

#[test]
fn mutant_rank_disordered_bucket_plan_is_rejected() {
    // Monotone and fully covering, but bucket 0 takes all of run 0 and
    // bucket 1 all of run 1 — concatenating the merges is unsorted.
    let a: Vec<u32> = (0..16).collect();
    let b: Vec<u32> = (0..16).collect();
    let views: Vec<&[u32]> = vec![&a, &b];
    let plan = bitonic_tpu::sort::MergePlan {
        cuts: vec![vec![0, 0], vec![16, 0], vec![16, 16]],
    };
    let err = check_bucket_plan(&views, &plan).unwrap_err();
    assert!(err.contains("earlier bucket reaches"), "{err}");
}

#[test]
fn mutant_collapsed_bucket_plan_is_rejected() {
    // Everything in one bucket: a valid order, but far beyond the
    // provable balance bound — the dup-heavy collapse the (key, run,
    // index) tie-break exists to prevent must never verify.
    let runs: Vec<Vec<u32>> = vec![vec![7; 64], vec![7; 64]];
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    let all: Vec<usize> = vec![64, 64];
    let plan = bitonic_tpu::sort::MergePlan {
        cuts: vec![vec![0, 0], all.clone(), all.clone(), all.clone(), all],
    };
    let err = check_bucket_plan(&views, &plan).unwrap_err();
    assert!(err.contains("provable bound"), "{err}");
}

// ---------------------------------------------------------------------
// Mutant 6: broken merge wiring — dropping `reverse_tail` violates the
// bitonic precondition; the merge-input grid must find a witness.
// ---------------------------------------------------------------------

#[test]
fn mutant_merge_without_reverse_tail_is_refuted() {
    let steps = canonical_steps(ArtifactKind::Merge, 64);
    match check_merge_steps(64, &steps, false, &opts()) {
        Outcome::Refuted { .. } => {}
        other => panic!("merge without reverse_tail not refuted: {other:?}"),
    }
    let mut dropped = canonical_steps(ArtifactKind::Merge, 64);
    dropped.pop();
    match check_merge_steps(64, &dropped, true, &opts()) {
        Outcome::Refuted { .. } => {}
        other => panic!("merge with dropped step not refuted: {other:?}"),
    }
}

// ---------------------------------------------------------------------
// End-to-end temp-dir fixtures.
// ---------------------------------------------------------------------

struct TempArtifacts {
    dir: PathBuf,
}

impl TempArtifacts {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!(
            "bitonic-analysis-mutations-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        Self { dir }
    }

    fn write(&self, name: &str, text: &str) {
        std::fs::write(self.dir.join(name), text).unwrap();
    }

    /// Minimal HLO text that passes `SortExecutor::compile` validation.
    fn hlo(shape: &str) -> String {
        format!("HloModule jit_sort\n\nENTRY main {{\n  p = {shape} parameter(0)\n}}\n")
    }
}

impl Drop for TempArtifacts {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

const MANIFEST_HEADER: &str = "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile";

#[test]
fn broken_manifest_fails_verify_plans_without_panicking() {
    let t = TempArtifacts::new("broken");
    // Row 1: dtype drift — manifest says uint32, HLO declares s32.
    // Row 2: non-power-of-two n. Row 3: dangling file reference.
    t.write(
        "manifest.tsv",
        &format!(
            "{MANIFEST_HEADER}\n\
             sort_drift\tsort\toptimized\t8\t64\tuint32\t0\t64\t1\tsort_drift.hlo.txt\n\
             sort_badn\tsort\toptimized\t8\t48\tuint32\t0\t16\t1\tsort_badn.hlo.txt\n\
             sort_gone\tsort\toptimized\t8\t64\tuint32\t0\t64\t1\tsort_gone.hlo.txt\n"
        ),
    );
    t.write("sort_drift.hlo.txt", &TempArtifacts::hlo("s32[8,64]"));
    t.write("sort_badn.hlo.txt", &TempArtifacts::hlo("u32[8,48]"));
    let report = verify_plans(&t.dir, &opts()).expect("verify_plans must not error out");
    assert!(report.has_fail());
    let failing: Vec<&str> = report
        .findings
        .iter()
        .filter(|f| f.verdict == Verdict::Fail)
        .map(|f| f.check.as_str())
        .collect();
    assert!(failing.contains(&"artifact.hlo"), "{failing:?}");
    assert!(failing.contains(&"artifact.shape"), "{failing:?}");
    assert!(failing.contains(&"artifact.file"), "{failing:?}");
    // The registry independently refuses to compile the same rows.
    assert!(failing.contains(&"network.compile"), "{failing:?}");
}

#[test]
fn stale_autotune_profile_warns_and_continues() {
    let t = TempArtifacts::new("stale-tune");
    t.write(
        "manifest.tsv",
        &format!("{MANIFEST_HEADER}\nsort_ok\tsort\toptimized\t8\t64\tuint32\t0\t64\t1\tsort_ok.hlo.txt\n"),
    );
    t.write("sort_ok.hlo.txt", &TempArtifacts::hlo("u32[8,64]"));
    // n=128 uint32 has no sort artifact in the manifest: a stale class.
    t.write(
        "autotune.tsv",
        "n\tdtype\tvariant\tblock\tinterleave\tthreads\trows_per_sec\n\
         128\tuint32\toptimized\t64\t4\t2\t123456.0\n",
    );
    let report = verify_plans(&t.dir, &opts()).expect("stale profile must not be fatal");
    assert!(!report.has_fail(), "{}", report.render_markdown());
    let stale = report
        .findings
        .iter()
        .find(|f| f.check == "artifact.autotune" && f.verdict == Verdict::Warn)
        .expect("stale tuned class must surface as a WARN");
    assert!(stale.detail.contains("stale"), "{}", stale.detail);
}

#[test]
fn checked_in_fixture_verifies_clean() {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    let o = VerifyOptions { samples: 32, ..opts() };
    let report = verify_plans(&dir, &o).expect("fixture verify");
    assert!(!report.has_fail(), "{}", report.render_markdown());
    // n=1024 classes get the real exhaustive proof...
    assert!(
        report.findings.iter().any(|f| f.detail.contains("per-phase 0-1 induction")),
        "{}",
        report.render_markdown()
    );
    // ...while n=65536 is above the cap and must be an explicit WARN,
    // never silently reported as proven.
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.verdict == Verdict::Warn && f.detail.contains("exceeds exhaustive cap")),
        "{}",
        report.render_markdown()
    );
}

#[test]
fn tile_dispatch_checker_covers_unpooled_ragged_batches() {
    // b=4, n=32, want=3: unpooled, single job spanning the buffer whose
    // length is not a tile multiple — regression for the checker itself
    // (caught by the python port before the rust side first compiled).
    let stats = check_tile_dispatch(4, 32, 3, 1).unwrap();
    assert!(!stats.pooled);
    assert_eq!(stats.r, 3);
    assert_eq!(stats.tiles, 2); // 3 rows + ragged 1-row tail
}
