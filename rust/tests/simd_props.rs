//! SIMD-vs-scalar bit-exactness property suite: every available
//! comparator ISA must be bit-identical to the scalar kernels at three
//! levels — the raw interleaved sweep kernels, whole `ExecutionPlan`s
//! with the ISA pinned, and the pooled device host — across u32/i32/f32
//! × sort/merge × ascending/descending × lane widths {1, 3, 4, 8, 16}
//! (3 exercises the vector-plus-ragged-tail split), with MAX-padded
//! rows and f32 NaN/±inf/±0 compared **as bits**, not by `==`.
//!
//! On a default build (or a non-AVX2 host) `available_isas()` is
//! `[scalar, portable]`, so the suite still proves the portable chunked
//! kernels; under `--features simd` on an AVX2 host it proves the
//! explicit intrinsics too. Nothing here is feature-gated.

use bitonic_tpu::runtime::{ArtifactKind, ExecutionPlan, PlanConfig};
use bitonic_tpu::sort::simd::{double_step_interleaved, step_interleaved};
use bitonic_tpu::sort::{bitonic_sort, KernelChoice, KernelIsa, SortKey};
use bitonic_tpu::workload::{Distribution, Generator};

/// Bit view of a key: the only equality the suite trusts (`==` on f32
/// conflates -0.0 with 0.0 and rejects NaN entirely).
trait Bits: SortKey + std::fmt::Debug {
    fn bits(self) -> u32;
}

impl Bits for u32 {
    fn bits(self) -> u32 {
        self
    }
}

impl Bits for i32 {
    fn bits(self) -> u32 {
        self as u32
    }
}

impl Bits for f32 {
    fn bits(self) -> u32 {
        self.to_bits()
    }
}

fn assert_bits_eq<T: Bits>(got: &[T], want: &[T], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.bits(), w.bits(), "{label}: divergence at {i} ({g:?} vs {w:?})");
    }
}

fn keys_u32(gen: &mut Generator, len: usize) -> Vec<u32> {
    let mut v = gen.u32s(len, Distribution::DupHeavy);
    if len >= 2 {
        v[0] = u32::MAX;
        v[1] = 0;
    }
    v
}

fn keys_i32(gen: &mut Generator, len: usize) -> Vec<i32> {
    let mut v: Vec<i32> = gen
        .u32s(len, Distribution::DupHeavy)
        .into_iter()
        .map(|x| x as i32)
        .collect();
    if len >= 2 {
        v[0] = i32::MIN;
        v[1] = i32::MAX;
    }
    v
}

fn keys_f32(gen: &mut Generator, len: usize) -> Vec<f32> {
    let mut v = gen.f32s(len, Distribution::Uniform);
    // Adversarial salt: both NaN signs, ±inf, both zeros — exactly the
    // values the AVX2 total-order bit mapping must keep where the
    // scalar comparator puts them.
    let salt = [f32::NAN, -f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0];
    for (i, s) in salt.into_iter().enumerate() {
        if i < len {
            v[i] = s;
        }
    }
    v
}

/// Level 1 — the raw sweep kernels. Walks the full single-step network
/// and the paired double-step schedule over an interleaved tile,
/// comparing the ISA under test against the scalar kernel **after every
/// step** (not just at the end), then checks the walk really sorted
/// every lane.
fn kernel_sweep<T: Bits>(make: fn(&mut Generator, usize) -> Vec<T>, dtype: &str) {
    let mut gen = Generator::new(0x51AD);
    for isa in KernelIsa::available_isas() {
        for lanes in [1usize, 3, 4, 8, 16] {
            for n in [64usize, 256] {
                let ctx = format!("{dtype} isa={} lanes={lanes} n={n}", isa.name());
                let fixture = make(&mut gen, n * lanes);

                let (mut a, mut b) = (fixture.clone(), fixture.clone());
                let mut k = 2;
                while k <= n {
                    let mut j = k / 2;
                    while j >= 1 {
                        step_interleaved(KernelIsa::Scalar, &mut a, k, j, lanes, 0, n);
                        step_interleaved(isa, &mut b, k, j, lanes, 0, n);
                        assert_bits_eq(&b, &a, &format!("{ctx} step k={k} j={j}"));
                        j /= 2;
                    }
                    k *= 2;
                }
                for l in 0..lanes {
                    let row: Vec<T> = (0..n).map(|e| b[e * lanes + l]).collect();
                    for w in row.windows(2) {
                        assert!(!w[1].total_lt(&w[0]), "{ctx}: lane {l} unsorted");
                    }
                }

                // The register-paired quad sweep, same contract
                // (j_hi >= 2, 2*j_hi <= k; leftover stride-1 single).
                let (mut a, mut b) = (fixture.clone(), fixture);
                let mut k = 2;
                while k <= n {
                    let mut j = k / 2;
                    while j >= 2 {
                        double_step_interleaved(KernelIsa::Scalar, &mut a, k, j, lanes, 0, n);
                        double_step_interleaved(isa, &mut b, k, j, lanes, 0, n);
                        assert_bits_eq(&b, &a, &format!("{ctx} double k={k} j={j}"));
                        j /= 4;
                    }
                    if j == 1 {
                        step_interleaved(KernelIsa::Scalar, &mut a, k, 1, lanes, 0, n);
                        step_interleaved(isa, &mut b, k, 1, lanes, 0, n);
                        assert_bits_eq(&b, &a, &format!("{ctx} leftover k={k}"));
                    }
                    k *= 2;
                }
            }
        }
    }
}

#[test]
fn kernel_sweeps_bit_exact_across_isas_u32() {
    kernel_sweep(keys_u32, "u32");
}

#[test]
fn kernel_sweeps_bit_exact_across_isas_i32() {
    kernel_sweep(keys_i32, "i32");
}

#[test]
fn kernel_sweeps_bit_exact_across_isas_f32() {
    kernel_sweep(keys_f32, "f32");
}

/// Level 2 — whole execution plans with the ISA pinned via
/// `PlanConfig::kernel`, across sort/merge × asc/desc × interleave
/// widths, each row MAX-padded in its back third (the coordinator
/// router's padding contract).
fn plan_sweep<T: Bits>(make: fn(&mut Generator, usize) -> Vec<T>, dtype: &str) {
    let n = 256usize;
    let mut gen = Generator::new(0x51AE);
    for isa in KernelIsa::available_isas() {
        for kind in [ArtifactKind::Sort, ArtifactKind::Merge] {
            for descending in [false, true] {
                for r in [1usize, 4, 8, 16] {
                    let ctx = format!(
                        "{dtype} isa={} {kind:?} desc={descending} R={r}",
                        isa.name()
                    );
                    let mut rows = make(&mut gen, r * n);
                    for row in rows.chunks_mut(n) {
                        for x in &mut row[n - n / 3..] {
                            *x = T::MAX_KEY;
                        }
                        if kind == ArtifactKind::Merge {
                            // Merge contract: halves sorted ascending.
                            bitonic_sort(&mut row[..n / 2]);
                            bitonic_sort(&mut row[n / 2..]);
                        }
                    }
                    let mk = |isa| {
                        ExecutionPlan::with_config(
                            kind,
                            n,
                            descending,
                            PlanConfig {
                                interleave: r,
                                kernel: KernelChoice::Fixed(isa),
                                ..Default::default()
                            },
                        )
                    };
                    let mut scratch = Vec::new();
                    let mut want = rows.clone();
                    mk(KernelIsa::Scalar).run_tile(&mut want, &mut scratch);
                    let mut got = rows;
                    mk(isa).run_tile(&mut got, &mut scratch);
                    assert_bits_eq(&got, &want, &ctx);
                }
            }
        }
    }
}

#[test]
fn plans_bit_exact_across_isas_u32() {
    plan_sweep(keys_u32, "u32");
}

#[test]
fn plans_bit_exact_across_isas_i32() {
    plan_sweep(keys_i32, "i32");
}

#[test]
fn plans_bit_exact_across_isas_f32() {
    plan_sweep(keys_f32, "f32");
}

/// Level 3 — the pooled device host end to end: registry, host thread,
/// tile pool. Every non-scalar ISA must return exactly what a
/// scalar-pinned host returns, over every fixture artifact.
#[test]
fn pooled_host_bit_exact_across_isas() {
    use bitonic_tpu::runtime::{spawn_device_host_with, HostConfig, Key};
    use bitonic_tpu::sort::network::Variant;
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `bitonic-tpu gen-artifacts`");
        return;
    }
    let host_with = |isa| {
        spawn_device_host_with(
            &dir,
            HostConfig {
                threads: 4,
                plan: PlanConfig {
                    interleave: 8,
                    kernel: KernelChoice::Fixed(isa),
                    ..Default::default()
                }
                .into(),
            },
        )
    };
    let (scalar, manifest) = host_with(KernelIsa::Scalar).unwrap();
    let mut gen = Generator::new(0x51AF);
    for isa in KernelIsa::available_isas() {
        if isa == KernelIsa::Scalar {
            continue;
        }
        let (host, _) = host_with(isa).unwrap();
        for meta in manifest.size_classes(Variant::Optimized) {
            let rows = gen.u32s(meta.batch * meta.n, Distribution::DupHeavy);
            let a = scalar.sort_u32(Key::of(meta), rows.clone()).unwrap();
            let b = host.sort_u32(Key::of(meta), rows).unwrap();
            assert_eq!(a, b, "{} isa={}", meta.name, isa.name());
        }
        host.shutdown();
    }
    scalar.shutdown();
}
