//! Serving-under-load regressions driven through the real TCP stack:
//!
//! * **Starvation** — the scheduler's stealing is unweighted, so a hot
//!   small class at saturation must not starve a trickle of large cold
//!   requests outright. The bound here is deliberately generous (it
//!   documents the gap, it does not pretend to close it — see ROADMAP's
//!   per-class admission-budget follow-up); the test exists so a future
//!   scheduler change that *fully* starves the cold class fails loudly.
//! * **Loadgen determinism** — the whole harness replays from `--seed`,
//!   which is what makes trajectory records comparable across runs.

use std::sync::Arc;
use std::time::Duration;

use bitonic_tpu::bench::loadgen::worker_seed;
use bitonic_tpu::bench::{run_loadgen, LoadMode, LoadgenConfig};
use bitonic_tpu::coordinator::net::{NetServer, NetServerConfig};
use bitonic_tpu::coordinator::{BatchSorter, Service, ServiceConfig};
use bitonic_tpu::sort::bitonic_sort;
use bitonic_tpu::workload::{Distribution, TrafficClass, TrafficGen, TrafficMix};

struct SlowMock {
    batch: usize,
    n: usize,
    delay: Duration,
}

impl BatchSorter for SlowMock {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
        std::thread::sleep(self.delay);
        for r in rows.chunks_mut(self.n) {
            bitonic_sort(r);
        }
        Ok(rows)
    }
}

/// A 15:1 hot/cold mix aimed at the two mock classes below. Both carry
/// the same SLO so the per-class miss rates are directly comparable.
fn contended_mix() -> TrafficMix {
    let slo = Some(Duration::from_millis(40));
    TrafficMix {
        classes: vec![
            TrafficClass {
                name: "hot",
                weight: 15,
                min_len: 64,
                max_len: 256,
                dist: Distribution::Uniform,
                descending: false,
                slo,
            },
            TrafficClass {
                name: "cold",
                weight: 1,
                min_len: 1024,
                max_len: 4096,
                dist: Distribution::Uniform,
                descending: false,
                slo,
            },
        ],
    }
}

#[test]
fn cold_class_is_not_fully_starved_at_hot_saturation() {
    // Two workers, both classes slow: the hot class alone can saturate
    // the pool, so the cold trickle only progresses if stealing ever
    // picks it up.
    let svc = Service::new(
        vec![
            Arc::new(SlowMock { batch: 4, n: 256, delay: Duration::from_millis(4) })
                as Arc<dyn BatchSorter>,
            Arc::new(SlowMock { batch: 2, n: 4096, delay: Duration::from_millis(4) })
                as Arc<dyn BatchSorter>,
        ],
        ServiceConfig { threads: 2, ..ServiceConfig::default() },
    );
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetServerConfig::default()).unwrap();

    let cfg = LoadgenConfig {
        mode: LoadMode::Closed,
        conns: 4,
        duration: Duration::from_millis(1500),
        seed: 7,
        mix: contended_mix(),
        timeout: Duration::from_secs(30),
    };
    let report = run_loadgen(&server.local_addr().to_string(), &cfg).unwrap();

    assert_eq!(report.protocol_errors(), 0, "wire path broke under load: {report:?}");
    let hot = report.class("hot").expect("hot class report");
    let cold = report.class("cold").expect("cold class report");
    assert!(hot.ok >= 10, "hot class barely ran: {hot:?}");
    // The regression proper: the cold class made real progress…
    assert!(cold.ok >= 1, "cold class fully starved: {cold:?}");
    assert!(cold.slo_tracked >= 1, "no cold answer was SLO-tracked: {cold:?}");
    // …and was not *unboundedly* starved. 0.95 is deliberately loose:
    // unweighted stealing is allowed to miss SLOs under pressure, it is
    // not allowed to strand the class (miss rate pinned at 1.0 with
    // latencies growing without bound).
    assert!(
        cold.slo_miss_rate() <= 0.95,
        "cold class effectively starved: miss rate {:.2} ({cold:?})",
        cold.slo_miss_rate()
    );

    // The service attributed the traffic per class.
    let st = svc.stats();
    assert!(st.classes[0].admitted.get() >= hot.ok, "hot admissions unaccounted");
    assert!(st.classes[1].admitted.get() >= cold.ok, "cold admissions unaccounted");
    assert!(st.classes[1].latency.count() >= 1);

    let mut server = server;
    server.request_shutdown();
    server.shutdown();
    svc.shutdown();
}

#[test]
fn traffic_streams_replay_exactly_from_the_worker_seed() {
    let mix = TrafficMix::serving();
    for worker in 0..3 {
        let seed = worker_seed(42, worker);
        let mut a = TrafficGen::new(mix.clone(), seed);
        let mut b = TrafficGen::new(mix.clone(), seed);
        for _ in 0..200 {
            let (ra, rb) = (a.next_request(), b.next_request());
            assert_eq!(ra, rb, "worker {worker} diverged from its own seed");
        }
    }
}

#[test]
fn different_workers_draw_different_streams() {
    let mix = TrafficMix::serving();
    let mut a = TrafficGen::new(mix.clone(), worker_seed(42, 0));
    let mut b = TrafficGen::new(mix, worker_seed(42, 1));
    let identical = (0..100)
        .filter(|_| {
            let (ra, rb) = (a.next_request(), b.next_request());
            ra.keys == rb.keys && ra.class == rb.class
        })
        .count();
    assert!(identical < 100, "two workers replayed the same stream");
}

#[test]
fn same_cli_seed_produces_identical_loadgen_request_sequences() {
    // End-to-end determinism of what `bitonic-tpu loadgen --seed` sends:
    // every (class, len, keys, order, slo) tuple replays, across every
    // worker the run would spawn.
    let conns = 4;
    for worker in 0..conns {
        let mut first = TrafficGen::new(TrafficMix::smoke(), worker_seed(1234, worker));
        let mut second = TrafficGen::new(TrafficMix::smoke(), worker_seed(1234, worker));
        let a: Vec<_> = (0..50).map(|_| first.next_request()).collect();
        let b: Vec<_> = (0..50).map(|_| second.next_request()).collect();
        assert_eq!(a, b);
    }
}
