//! Parity guard: the launch schedules three independent layers derive —
//! `Network::launches`/`merge_launches` (simulator + native executor),
//! `python/compile/model.py::plan`/`merge_plan` (the Pallas planner), and
//! the checked-in golden table — must agree on launch counts for the
//! fixture menu shapes. The Python side asserts the same table in
//! `python/tests/test_launch_parity.py`, so the simulator, the Python
//! planner, and the executor cannot drift apart silently.
//!
//! Regenerate `tests/data/launch_counts_golden.tsv` only when the fusion
//! algebra itself changes, and update both test-suites' expectations
//! together.

use bitonic_tpu::sort::network::{Network, Variant};

const GOLDEN: &str = include_str!("data/launch_counts_golden.tsv");

#[test]
fn launch_counts_match_golden_table() {
    let mut lines = GOLDEN.lines();
    assert_eq!(
        lines.next(),
        Some("kind\tvariant\tn\tblock\tlaunches"),
        "golden table header changed"
    );
    let mut checked = 0;
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        assert_eq!(f.len(), 5, "malformed golden row {line:?}");
        let (kind, variant, n, block, want): (&str, Variant, usize, usize, usize) = (
            f[0],
            Variant::parse(f[1]).expect("bad variant in golden table"),
            f[2].parse().unwrap(),
            f[3].parse().unwrap(),
            f[4].parse().unwrap(),
        );
        let net = Network::new(n);
        let got = match kind {
            "sort" => net.launches(variant, block).len(),
            "merge" => net.merge_launches(variant, block).len(),
            other => panic!("unknown kind {other:?} in golden table"),
        };
        assert_eq!(
            got, want,
            "{kind} {variant:?} n={n} block={block}: rust derives {got} launches, golden says {want}"
        );
        checked += 1;
    }
    // The fixture menu sweep: 8 shapes x 3 variants x 2 blocks.
    assert_eq!(checked, 48, "golden table row count changed");
}

#[test]
fn golden_table_covers_the_fixture_menu() {
    // Every (kind, n) the checked-in artifact fixture serves must appear
    // in the golden table, so a menu extension forces a parity update.
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}");
        return;
    }
    let manifest = bitonic_tpu::runtime::Manifest::load(&dir).unwrap();
    for meta in &manifest.entries {
        let kind = match meta.kind {
            bitonic_tpu::runtime::ArtifactKind::Sort => "sort",
            bitonic_tpu::runtime::ArtifactKind::Merge => "merge",
        };
        let needle = format!("{kind}\t{}\t{}\t", meta.variant.name(), meta.n);
        assert!(
            GOLDEN.lines().any(|l| l.starts_with(&needle)),
            "fixture artifact {} ({kind}, n={}) missing from golden table",
            meta.name,
            meta.n
        );
    }
}
