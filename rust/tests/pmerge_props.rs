//! Property suite for the splitter-partitioned parallel merge
//! (`sort::pmerge`): across dtypes × the survey distributions × fan-ins
//! × worker counts, the parallel merge must be **bit-exact** with the
//! serial loser-tree merge (`sort::kmerge::kway_merge`) — same bytes,
//! not just the same multiset — plus the partition invariants the
//! dispatch relies on (coverage, monotonicity, rank-ordered boundaries,
//! the distribution-free balance bound).
//!
//! The hazards the ISSUE names are all salted in:
//! * positional run exhaustion — runs are MAX-padded like the
//!   hierarchical sorter's ragged tail, and the pads must merge to the
//!   back, not truncate a run early;
//! * f32 total order — NaN (both payload classes), ±inf and -0.0 are
//!   injected and compared **as bits** (`to_bits`), so a NaN swallowed
//!   by a `==` somewhere cannot hide;
//! * splitter duplicates — the dup-heavy distribution drives the
//!   tie-break, and bucket sizes are asserted against `balance_bound`.

use bitonic_tpu::sort::pmerge::{balance_bound, BUCKETS_PER_THREAD};
use bitonic_tpu::sort::{kway_merge, plan_partition, pmerge, SortKey};
use bitonic_tpu::util::threadpool::ThreadPool;
use bitonic_tpu::workload::{Distribution, Generator};

const FAN_INS: [usize; 3] = [2, 3, 16];
const THREADS: [usize; 3] = [1, 2, 8];

/// Split `keys` into `k` runs of deliberately uneven lengths (the last
/// run takes the remainder), MAX-pad every run to its power-of-two
/// ceiling the way the hierarchical sorter pads its ragged tail tile,
/// and sort each under the total order.
fn make_runs<T: SortKey>(mut keys: Vec<T>, k: usize, pad: bool) -> Vec<Vec<T>> {
    let n = keys.len();
    let mut runs: Vec<Vec<T>> = Vec::with_capacity(k);
    for i in 0..k {
        // Uneven cuts: run i gets a share growing with i.
        let take = if i + 1 == k { keys.len() } else { (n / k / 2) * (1 + i % 3) };
        let take = take.min(keys.len());
        let rest = keys.split_off(take);
        let mut run = std::mem::replace(&mut keys, rest);
        // Pad BEFORE sorting, like the hierarchical sorter pads the
        // ragged tail tile and then device-sorts it: NaN ranks above
        // T::MAX_KEY (= +inf for floats), so padding after the sort
        // would break the sorted-run precondition.
        if pad && !run.is_empty() {
            let ceil = run.len().next_power_of_two();
            run.resize(ceil, T::MAX_KEY);
        }
        run.sort_unstable_by(|a, b| {
            if a.total_lt(b) {
                std::cmp::Ordering::Less
            } else if b.total_lt(a) {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Equal
            }
        });
        runs.push(run);
    }
    runs
}

/// The serial oracle vs the parallel merge, compared via the caller's
/// byte projection.
fn assert_bit_exact<T: SortKey, B: PartialEq + std::fmt::Debug>(
    runs: &[Vec<T>],
    pool: &ThreadPool,
    parts: usize,
    bits: impl Fn(&T) -> B,
    label: &str,
) {
    let views: Vec<&[T]> = runs.iter().map(|r| r.as_slice()).collect();
    let mut want = Vec::new();
    kway_merge(&views, &mut want);
    let mut got = Vec::new();
    pmerge(&views, pool, parts, &mut got).unwrap_or_else(|e| panic!("{label}: {e}"));
    let want_bits: Vec<B> = want.iter().map(&bits).collect();
    let got_bits: Vec<B> = got.iter().map(&bits).collect();
    assert_eq!(got_bits, want_bits, "{label}: parallel merge is not bit-exact");
}

/// The partition invariants for one planned fan-in: every key in exactly
/// one bucket, monotone cut columns, and no bucket above the provable
/// balance bound.
fn assert_partition_invariants<T: SortKey>(runs: &[Vec<T>], parts: usize, label: &str) {
    let views: Vec<&[T]> = runs.iter().map(|r| r.as_slice()).collect();
    let plan = plan_partition(&views, parts);
    let lens: Vec<usize> = views.iter().map(|r| r.len()).collect();
    assert_eq!(plan.cuts[0], vec![0; views.len()], "{label}: row 0 not zero");
    assert_eq!(*plan.cuts.last().unwrap(), lens, "{label}: last row != lens");
    for w in plan.cuts.windows(2) {
        for q in 0..views.len() {
            assert!(w[0][q] <= w[1][q], "{label}: non-monotone cut for run {q}");
        }
    }
    let total: usize = lens.iter().sum();
    let covered: usize = plan.bucket_sizes().iter().sum();
    assert_eq!(covered, total, "{label}: buckets cover {covered} of {total}");
    assert_eq!(*plan.bucket_offsets().last().unwrap(), total, "{label}: offsets");
    let bound = balance_bound(&lens, parts);
    assert!(
        plan.largest_bucket() <= bound,
        "{label}: largest bucket {} above the provable bound {bound}",
        plan.largest_bucket()
    );
}

#[test]
fn u32_parallel_merge_is_bit_exact_across_grid() {
    for &threads in &THREADS {
        let pool = ThreadPool::new(threads, 2 * threads);
        for dist in Distribution::SURVEY {
            for &k in &FAN_INS {
                for pad in [false, true] {
                    let mut gen =
                        Generator::new(0xA11C_E5 ^ ((k as u64) << 8) ^ threads as u64);
                    let keys = gen.u32s(4096, dist);
                    let runs = make_runs(keys, k, pad);
                    let label = format!(
                        "u32 {} k={k} threads={threads} pad={pad}",
                        dist.name()
                    );
                    assert_bit_exact(
                        &runs,
                        &pool,
                        threads * BUCKETS_PER_THREAD,
                        |&x| x,
                        &label,
                    );
                    assert_partition_invariants(&runs, threads * BUCKETS_PER_THREAD, &label);
                }
            }
        }
    }
}

#[test]
fn i32_parallel_merge_is_bit_exact_across_grid() {
    for &threads in &THREADS {
        let pool = ThreadPool::new(threads, 2 * threads);
        for dist in Distribution::SURVEY {
            for &k in &FAN_INS {
                let mut gen =
                    Generator::new(0x5133_D ^ ((k as u64) << 4) ^ threads as u64);
                // Sign-flip cast: exercises negative keys and i32::MIN/MAX
                // without needing a dedicated generator.
                let keys: Vec<i32> =
                    gen.u32s(4096, dist).into_iter().map(|x| x as i32).collect();
                let runs = make_runs(keys, k, true);
                let label = format!("i32 {} k={k} threads={threads}", dist.name());
                assert_bit_exact(&runs, &pool, threads * BUCKETS_PER_THREAD, |&x| x, &label);
                assert_partition_invariants(&runs, threads * BUCKETS_PER_THREAD, &label);
            }
        }
    }
}

#[test]
fn f32_parallel_merge_is_bit_exact_with_salted_specials() {
    for &threads in &THREADS {
        let pool = ThreadPool::new(threads, 2 * threads);
        for dist in Distribution::SURVEY {
            for &k in &FAN_INS {
                let mut gen =
                    Generator::new(0xF10A_7 ^ ((k as u64) << 4) ^ threads as u64);
                let mut keys = gen.f32s(4096, dist);
                // Salt every special the total order must keep distinct;
                // two NaN payloads so bit-compare can see a swap.
                let specials = [
                    f32::NAN,
                    f32::from_bits(0x7FC0_0001),
                    f32::INFINITY,
                    f32::NEG_INFINITY,
                    -0.0f32,
                    0.0f32,
                ];
                for (i, s) in specials.iter().enumerate() {
                    let stride = keys.len() / specials.len();
                    keys[i * stride] = *s;
                }
                let runs = make_runs(keys, k, true);
                let label = format!("f32 {} k={k} threads={threads}", dist.name());
                assert_bit_exact(
                    &runs,
                    &pool,
                    threads * BUCKETS_PER_THREAD,
                    |x| x.to_bits(),
                    &label,
                );
                assert_partition_invariants(&runs, threads * BUCKETS_PER_THREAD, &label);
            }
        }
    }
}

#[test]
fn exhausted_and_empty_runs_merge_like_the_oracle() {
    let pool = ThreadPool::new(4, 8);
    // All-pad runs, an empty run, and one real run: positional
    // exhaustion everywhere the loser tree can hit it.
    let runs: Vec<Vec<u32>> = vec![
        vec![u32::MAX; 8],
        vec![],
        vec![3, 9, 27, u32::MAX, u32::MAX],
        vec![u32::MAX; 2],
    ];
    assert_bit_exact(&runs, &pool, 8, |&x| x, "max-padded fan-in");
    assert_partition_invariants(&runs, 8, "max-padded fan-in");
}

#[test]
fn dup_heavy_partition_never_collapses() {
    // The adversarial case for value-ranked splitters: one key value.
    // The (key, run, index) rank must still split near-evenly.
    let pool = ThreadPool::new(8, 16);
    let runs: Vec<Vec<u32>> = (0..16).map(|_| vec![99u32; 256]).collect();
    let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
    let parts = 8 * BUCKETS_PER_THREAD;
    let plan = plan_partition(&views, parts);
    assert!(plan.parts() > 1, "all-equal keys collapsed the partition");
    assert_partition_invariants(&runs, parts, "dup-heavy");
    assert_bit_exact(&runs, &pool, parts, |&x| x, "dup-heavy");
}
