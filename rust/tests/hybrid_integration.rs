//! Integration tests of the out-of-core hybrid sorter over the real
//! artifacts (skipped with a message when no artifacts directory exists).

use bitonic_tpu::runtime::spawn_device_host;
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{is_sorted, quicksort, same_multiset, HybridSorter};
use bitonic_tpu::workload::{Distribution, Generator};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `python -m compile.aot`");
        None
    }
}

#[test]
fn hybrid_sorts_beyond_largest_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    if manifest.merge_classes().is_empty() {
        eprintln!("SKIP: no merge artifacts (quick mode?)");
        return;
    }
    let sorter = HybridSorter::new(handle, &manifest, Variant::Optimized).unwrap();
    let chunk = sorter.chunk();
    let mut gen = Generator::new(0x4B1D);
    // 3.5 chunks: exercises full pairs, a partial pair, and (depending on
    // the merge menu) the CPU tail.
    let n = chunk * 3 + chunk / 2;
    let orig = gen.u32s(n, Distribution::Uniform);
    let mut v = orig.clone();
    let stats = sorter.sort(&mut v).unwrap();
    assert_eq!(v.len(), n);
    assert!(is_sorted(&v));
    assert!(same_multiset(&orig, &v));
    assert!(stats.device_sorts >= 1, "{stats:?}");
    assert!(
        stats.device_merges + stats.cpu_merges >= 1,
        "no merging happened: {stats:?}"
    );
}

#[test]
fn hybrid_matches_quicksort_various_lengths() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    if manifest.merge_classes().is_empty() {
        eprintln!("SKIP: no merge artifacts");
        return;
    }
    let sorter = HybridSorter::new(handle, &manifest, Variant::Optimized).unwrap();
    let chunk = sorter.chunk();
    let mut gen = Generator::new(0x4B2D);
    for n in [
        0,
        1,
        17,
        chunk - 1,
        chunk,
        chunk + 1,
        2 * chunk,
        2 * chunk + 3,
        4 * chunk,
    ] {
        let orig = gen.u32s(n, Distribution::DupHeavy);
        let mut ours = orig.clone();
        sorter.sort(&mut ours).unwrap();
        let mut want = orig;
        quicksort(&mut want);
        assert_eq!(ours, want, "n={n}");
    }
}

#[test]
fn hybrid_handles_max_keys() {
    // Real u32::MAX keys must survive MAX-padding (multiset equality by
    // value — see hybrid.rs stage-2 comment).
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    if manifest.merge_classes().is_empty() {
        eprintln!("SKIP: no merge artifacts");
        return;
    }
    let sorter = HybridSorter::new(handle, &manifest, Variant::Optimized).unwrap();
    let chunk = sorter.chunk();
    let mut gen = Generator::new(9);
    let n = 2 * chunk + chunk / 3;
    let mut orig = gen.u32s(n, Distribution::Uniform);
    // Salt with MAX keys.
    for i in (0..n).step_by(97) {
        orig[i] = u32::MAX;
    }
    let mut ours = orig.clone();
    sorter.sort(&mut ours).unwrap();
    let mut want = orig;
    quicksort(&mut want);
    assert_eq!(ours, want);
    assert_eq!(
        ours.iter().filter(|&&x| x == u32::MAX).count(),
        n.div_ceil(97),
        "MAX keys lost or duplicated"
    );
}

#[test]
fn hybrid_small_chunk_runs_deep_device_merge_tree() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    if manifest.merge_classes().is_empty() {
        eprintln!("SKIP: no merge artifacts");
        return;
    }
    // chunk = 1024 with merge artifacts at 2^11 and 2^13 ⇒ two device
    // merge levels, then CPU tail.
    let sorter =
        HybridSorter::with_chunk(handle, &manifest, Variant::Optimized, 1024).unwrap();
    let mut gen = Generator::new(0xDEEB);
    let n = 1024 * 9 + 123; // 9.x chunks → full pairs + partial + lone
    let orig = gen.u32s(n, Distribution::Uniform);
    let mut v = orig.clone();
    let stats = sorter.sort(&mut v).unwrap();
    assert!(is_sorted(&v));
    assert!(same_multiset(&orig, &v));
    assert!(
        stats.device_merges >= 2,
        "expected a multi-level device merge tree: {stats:?}"
    );
}

#[test]
fn hybrid_all_distributions() {
    let Some(dir) = artifacts_dir() else { return };
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    if manifest.merge_classes().is_empty() {
        eprintln!("SKIP: no merge artifacts");
        return;
    }
    let sorter = HybridSorter::new(handle, &manifest, Variant::Optimized).unwrap();
    let chunk = sorter.chunk();
    let mut gen = Generator::new(0xD157);
    for dist in Distribution::ALL {
        let orig = gen.u32s(2 * chunk + 5, dist);
        let mut v = orig.clone();
        sorter.sort(&mut v).unwrap();
        assert!(is_sorted(&v), "{}", dist.name());
        assert!(same_multiset(&orig, &v), "{}", dist.name());
    }
}
