//! End-to-end tests for the bench trajectory subsystem: matrix sweep →
//! schema-validated JSON on disk → deterministic RESULTS.md, including
//! the device substrate over the checked-in artifact fixture.

use std::path::PathBuf;
use std::time::Duration;

use bitonic_tpu::bench::matrix::{run_matrix, run_pass_ablation, DeviceCtx, MatrixConfig};
use bitonic_tpu::bench::{render_results, Bench, BenchRecord, MatrixDtype, Substrate, Trajectory};
use bitonic_tpu::runtime::{spawn_device_host_with, HostConfig};
use bitonic_tpu::workload::Distribution;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("bitonic-tpu-bench-schema-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn tiny_bench() -> Bench {
    Bench {
        warmup: 0,
        min_iters: 1,
        max_iters: 2,
        target: Duration::from_millis(5),
    }
}

fn tiny_config() -> MatrixConfig {
    MatrixConfig {
        substrates: Substrate::ALL.to_vec(),
        dists: vec![
            Distribution::Uniform,
            Distribution::Sorted,
            Distribution::Reverse,
            Distribution::DupHeavy,
        ],
        dtypes: MatrixDtype::ALL.to_vec(),
        sizes: vec![256, 1024],
        threads: 2,
        bench: tiny_bench(),
        seed: 0x7E57_BE,
    }
}

/// The full pipeline on disk, CPU substrates only: run → append →
/// re-load (validating) → render, twice, byte-identical.
#[test]
fn matrix_to_trajectory_to_report_pipeline() {
    let path = tmp("pipeline.json");
    let _ = std::fs::remove_file(&path);

    let cfg = tiny_config();
    let mut records = run_matrix(&cfg, None).unwrap();
    records.extend(run_pass_ablation(&cfg.sizes, &cfg.bench, cfg.seed));
    assert!(!records.is_empty());
    let total = Trajectory::append_to(&path, records).unwrap();

    let t = Trajectory::load(&path).unwrap();
    assert_eq!(t.records.len(), total);

    // Acceptance-shaped coverage: ≥ 4 substrates × ≥ 3 dists × ≥ 2 dtypes.
    let distinct = |f: &dyn Fn(&BenchRecord) -> String| {
        let mut v: Vec<String> = t.records.iter().map(f).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    assert!(distinct(&|r| r.substrate.clone()).len() >= 4);
    assert!(distinct(&|r| r.dist.clone()).len() >= 3);
    assert!(distinct(&|r| r.dtype.clone()).len() >= 2);

    // Deterministic report: same JSON → byte-identical markdown, and a
    // re-saved (re-serialised) trajectory renders identically too.
    let a = render_results(&t);
    let b = render_results(&Trajectory::load(&path).unwrap());
    assert_eq!(a, b);
    let resaved = tmp("pipeline_resaved.json");
    t.save(&resaved).unwrap();
    assert_eq!(render_results(&Trajectory::load(&resaved).unwrap()), a);

    // The report carries the survey matrix, ablation and headline quote.
    assert!(a.contains("## Survey matrix"), "{a}");
    assert!(a.contains("## Launch-fusion ablation"), "{a}");
    assert!(a.contains("nearly 20 times"), "{a}");
    assert!(a.contains("quick ÷ executor"), "{a}");
}

/// The device substrate routes through a real device host (registry +
/// plan policy) over the checked-in fixture, and its records land with
/// batch, artifact and speedup annotations.
#[test]
fn device_substrate_routes_through_registry() {
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    let Ok((handle, manifest)) = spawn_device_host_with(
        &dir,
        HostConfig {
            threads: 2,
            ..HostConfig::default()
        },
    ) else {
        eprintln!("no artifacts fixture — skipping device matrix test");
        return;
    };
    let ctx = DeviceCtx {
        handle,
        manifest,
        threads: 2,
    };
    let cfg = MatrixConfig {
        substrates: vec![Substrate::Quicksort, Substrate::BitonicExecutor],
        dists: vec![Distribution::Uniform],
        dtypes: MatrixDtype::ALL.to_vec(),
        sizes: vec![1024],
        threads: 2,
        bench: tiny_bench(),
        seed: 1,
    };
    let records = run_matrix(&cfg, Some(&ctx)).unwrap();
    ctx.handle.shutdown();

    // The fixture ships n=1024 sort artifacts for all three dtypes.
    let device: Vec<&BenchRecord> = records
        .iter()
        .filter(|r| r.substrate == "bitonic-executor")
        .collect();
    assert_eq!(device.len(), 3, "u32/i32/f32 executor cells: {records:?}");
    for r in device {
        assert_eq!(r.n, 1024);
        assert!(r.batch >= 1);
        assert!(r.extra_str("artifact").is_some());
        assert_eq!(r.extra_f64("threads"), Some(2.0));
        if r.ms > 0.0 {
            assert!(r.extra_f64("speedup_vs_quicksort").is_some());
        }
    }

    // And the report's headline section can pair them with quicksort.
    let mut t = Trajectory::new();
    for r in records {
        t.push(r);
    }
    let out = render_results(&t);
    assert!(out.contains("bitonic-executor"), "{out}");
}

/// Malformed trajectories fail loudly at load (the satellite acceptance:
/// a corrupt file must never feed the report).
#[test]
fn malformed_trajectory_rejected_end_to_end() {
    let path = tmp("corrupt.json");
    // Truncated JSON.
    std::fs::write(&path, "{\"schema\": \"bitonic-tpu-bench-trajectory\",").unwrap();
    assert!(Trajectory::load(&path).is_err());
    // Valid JSON, wrong shape.
    std::fs::write(&path, "[1, 2, 3]\n").unwrap();
    assert!(Trajectory::load(&path).is_err());
    // Valid trajectory with one record missing a required field.
    let mut t = Trajectory::new();
    t.push(BenchRecord::new("matrix", "quicksort", "uniform", "u32", 64).with_ms(0.5));
    let text = t.to_json().render().replace("\"dist\": \"uniform\",\n", "");
    std::fs::write(&path, text).unwrap();
    let err = format!("{:#}", Trajectory::load(&path).unwrap_err());
    assert!(err.contains("dist"), "{err}");
}

/// Empty and single-record trajectories render without panicking — via
/// the same load/render path the CLI uses.
#[test]
fn report_smoke_empty_and_single() {
    let path = tmp("empty.json");
    Trajectory::new().save(&path).unwrap();
    let out = render_results(&Trajectory::load(&path).unwrap());
    assert!(out.contains("No records yet"), "{out}");

    let mut t = Trajectory::new();
    t.push(
        BenchRecord::new("matrix", "quicksort", "uniform", "u32", 1024)
            .with_ms(0.25)
            .with_extra("note", "single"),
    );
    t.save(&path).unwrap();
    let out = render_results(&Trajectory::load(&path).unwrap());
    assert!(out.contains("Records: 1"), "{out}");
    assert!(out.contains("quicksort"), "{out}");
}
