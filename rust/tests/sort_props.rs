//! Property-based tests of the CPU sort substrates (ISSUE 1 satellite):
//! every sort in `sort/` must agree with `slice::sort_unstable` (or
//! `sort_by(total_cmp)` for floats) on u32/u64/f32 inputs across random,
//! sorted, reversed, and duplicate-heavy distributions, using the in-repo
//! `util::prop` framework.

use bitonic_tpu::sort::{
    bitonic_sort_padded, bitonic_sort_parallel_padded, heapsort, mergesort, oddeven_sort,
    quicksort, radix_sort_u32,
};
use bitonic_tpu::sort::radix::radix_sort_u64;
use bitonic_tpu::util::prop::{check_with, Config, Strategy};
use bitonic_tpu::workload::rng::Pcg32;
use bitonic_tpu::workload::{Distribution, Generator};

/// A generated workload: a distribution shape, a length (including 0 and
/// non-powers-of-two), and a seed for the deterministic generator.
#[derive(Clone, Debug)]
struct Workload {
    dist: Distribution,
    len: usize,
    seed: u64,
}

struct WorkloadStrategy {
    max_len: usize,
}

const DISTS: [Distribution; 4] = [
    Distribution::Uniform,
    Distribution::Sorted,
    Distribution::Reverse,
    Distribution::DupHeavy,
];

impl Strategy for WorkloadStrategy {
    type Value = Workload;
    fn sample(&self, rng: &mut Pcg32) -> Workload {
        Workload {
            dist: DISTS[rng.next_below(DISTS.len() as u32) as usize],
            len: rng.next_below(self.max_len as u32 + 1) as usize,
            seed: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &Workload) -> Vec<Workload> {
        let mut out = Vec::new();
        if v.len > 0 {
            out.push(Workload { len: 0, ..v.clone() });
            out.push(Workload {
                len: v.len / 2,
                ..v.clone()
            });
            out.push(Workload {
                len: v.len - 1,
                ..v.clone()
            });
        }
        out
    }
}

fn config() -> Config {
    Config {
        cases: 48,
        ..Config::default()
    }
}

#[test]
fn u32_sorts_agree_with_std() {
    type SortFn = fn(&mut Vec<u32>);
    let sorts: Vec<(&str, SortFn)> = vec![
        ("quicksort", |v| quicksort(v)),
        ("heapsort", |v| heapsort(v)),
        ("mergesort", |v| mergesort(v)),
        ("oddeven", |v| oddeven_sort(v)),
        ("radix_u32", |v| radix_sort_u32(v)),
        ("bitonic_padded", |v| bitonic_sort_padded(v)),
        ("bitonic_parallel_padded", |v| {
            bitonic_sort_parallel_padded(v, 4)
        }),
    ];
    check_with(config(), &WorkloadStrategy { max_len: 2048 }, |w| {
        let keys = Generator::new(w.seed).u32s(w.len, w.dist);
        let mut want = keys.clone();
        want.sort_unstable();
        for (name, sort) in &sorts {
            let mut got = keys.clone();
            sort(&mut got);
            if got != want {
                return Err(format!(
                    "{name} disagrees with sort_unstable on {:?} len={}",
                    w.dist, w.len
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn u64_sorts_agree_with_std() {
    type SortFn = fn(&mut Vec<u64>);
    let sorts: Vec<(&str, SortFn)> = vec![
        ("quicksort", |v| quicksort(v)),
        ("heapsort", |v| heapsort(v)),
        ("mergesort", |v| mergesort(v)),
        ("radix_u64", |v| radix_sort_u64(v)),
        ("bitonic_padded", |v| bitonic_sort_padded(v)),
    ];
    check_with(config(), &WorkloadStrategy { max_len: 1024 }, |w| {
        let keys = Generator::new(w.seed).u64s(w.len, w.dist);
        let mut want = keys.clone();
        want.sort_unstable();
        for (name, sort) in &sorts {
            let mut got = keys.clone();
            sort(&mut got);
            if got != want {
                return Err(format!(
                    "{name} disagrees with sort_unstable on {:?} len={}",
                    w.dist, w.len
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn f32_sorts_agree_with_total_cmp() {
    type SortFn = fn(&mut Vec<f32>);
    let sorts: Vec<(&str, SortFn)> = vec![
        ("quicksort", |v| quicksort(v)),
        ("heapsort", |v| heapsort(v)),
        ("mergesort", |v| mergesort(v)),
        ("bitonic_padded", |v| bitonic_sort_padded(v)),
    ];
    check_with(config(), &WorkloadStrategy { max_len: 1024 }, |w| {
        let keys = Generator::new(w.seed).f32s(w.len, w.dist);
        let mut want = keys.clone();
        want.sort_by(f32::total_cmp);
        for (name, sort) in &sorts {
            let mut got = keys.clone();
            sort(&mut got);
            // Bitwise comparison: total order distinguishes -0.0 / 0.0,
            // and the generator only emits finite values.
            let same = got.len() == want.len()
                && got
                    .iter()
                    .zip(&want)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same {
                return Err(format!(
                    "{name} disagrees with sort_by(total_cmp) on {:?} len={}",
                    w.dist, w.len
                ));
            }
        }
        Ok(())
    });
}
