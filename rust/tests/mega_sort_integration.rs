//! Integration tests above the old 64K fixture ceiling: natively
//! generated artifact grids (`runtime::genart`), merged menu discovery,
//! and hybrid/hierarchical-vs-device bit-exactness on mega rows — the
//! carried-over PR 1 follow-up the ceiling blocked.
//!
//! The generated classes are synthesized into per-test temp dirs, so
//! these tests run anywhere the crate builds (no fixture beyond the
//! checked-in `rust/artifacts/` menu, which some tests also merge in).

use bitonic_tpu::runtime::host::spawn_manifest;
use bitonic_tpu::runtime::{
    generate_artifacts, spawn_device_host, spawn_device_host_discovered, Dtype, GenSpec,
    HostConfig, Key, Manifest,
};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{is_sorted, quicksort, same_multiset, HierarchicalSorter, HybridSorter};
use bitonic_tpu::workload::{Distribution, Generator};

fn fixture_dir() -> Option<std::path::PathBuf> {
    let dir = bitonic_tpu::runtime::default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `bitonic-tpu gen-artifacts`");
        None
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bitonic-mega-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Above-ceiling device classes, every dtype × order, against a CPU
/// total-order oracle — bitwise.
#[test]
fn generated_device_classes_bit_exact_above_64k() {
    let n = 1 << 17; // first class above the fixture's 64K ceiling
    let dir = temp_dir("dtypes");
    let specs: Vec<GenSpec> = [Dtype::U32, Dtype::I32, Dtype::F32]
        .into_iter()
        .flat_map(|d| [GenSpec::sort(n, 1, d, false), GenSpec::sort(n, 1, d, true)])
        .collect();
    generate_artifacts(&dir, &specs).unwrap();
    let (handle, manifest) = spawn_device_host(&dir).unwrap();
    let mut gen = Generator::new(0xBEEF_CAFE);

    for descending in [false, true] {
        // u32: uniform + MAX/MIN salt.
        let mut input = gen.u32s(n, Distribution::Uniform);
        input[0] = u32::MAX;
        input[1] = 0;
        let meta = manifest
            .find(Variant::Optimized, 1, n, Dtype::U32, descending)
            .unwrap();
        let got = handle.sort_u32(Key::of(meta), input.clone()).unwrap();
        let mut want = input;
        want.sort_unstable();
        if descending {
            want.reverse();
        }
        assert_eq!(got, want, "u32 desc={descending}");

        // i32: raw-cast signed keys, extremes included.
        let mut input: Vec<i32> = gen.u32s(n, Distribution::Uniform).into_iter().map(|x| x as i32).collect();
        input[0] = i32::MIN;
        input[1] = i32::MAX;
        let meta = manifest
            .find(Variant::Optimized, 1, n, Dtype::I32, descending)
            .unwrap();
        let got = handle.sort_i32(Key::of(meta), input.clone()).unwrap();
        let mut want = input;
        want.sort_unstable();
        if descending {
            want.reverse();
        }
        assert_eq!(got, want, "i32 desc={descending}");

        // f32: uniform + ±inf (+ canonical NaN on the ascending side);
        // the oracle is the IEEE total order, compared bit-for-bit.
        let mut input = gen.f32s(n, Distribution::Uniform);
        input[0] = f32::INFINITY;
        input[1] = f32::NEG_INFINITY;
        if !descending {
            input[2] = f32::NAN;
        }
        let meta = manifest
            .find(Variant::Optimized, 1, n, Dtype::F32, descending)
            .unwrap();
        let got = handle.sort_f32(Key::of(meta), input.clone()).unwrap();
        let mut want = input;
        want.sort_by(f32::total_cmp);
        if descending {
            want.reverse();
        }
        let (got_bits, want_bits): (Vec<u32>, Vec<u32>) = (
            got.iter().map(|x| x.to_bits()).collect(),
            want.iter().map(|x| x.to_bits()).collect(),
        );
        assert_eq!(got_bits, want_bits, "f32 desc={descending}");
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The satellite's headline: hybrid, hierarchical, and the flat device
/// path (via a generated 256K artifact) must agree bitwise on a
/// MAX-salted ragged mega-row — the device path MAX-padded up to shape,
/// the CPU-side drivers handling raggedness themselves.
#[test]
fn hybrid_and_hierarchical_match_device_above_the_ceiling() {
    let Some(fixture) = fixture_dir() else { return };
    let mega = 1 << 18;
    let gen_dir = temp_dir("crosscheck");
    generate_artifacts(&gen_dir, &[GenSpec::sort(mega, 1, Dtype::U32, false)]).unwrap();
    let manifest = Manifest::load_merged(&fixture, &gen_dir).unwrap();
    let (handle, manifest) = spawn_manifest(manifest, HostConfig::default()).unwrap();

    let n = mega - 777; // ragged: forces MAX padding everywhere
    let mut gen = Generator::new(0x64_000);
    let mut input = gen.u32s(n, Distribution::Uniform);
    for i in (0..n).step_by(131) {
        input[i] = u32::MAX; // real MAX keys must survive the padding
    }

    let mut oracle = input.clone();
    quicksort(&mut oracle);

    // Flat device path over the generated 256K artifact.
    let meta = manifest
        .find(Variant::Optimized, 1, mega, Dtype::U32, false)
        .expect("merged menu must contain the generated mega class");
    let mut padded = input.clone();
    padded.resize(mega, u32::MAX);
    let device = handle.sort_u32(Key::of(meta), padded).unwrap();
    assert_eq!(&device[..n], &oracle[..], "device vs oracle");

    // Hierarchical: fixture-sized tiles + loser-tree merge.
    let hier = HierarchicalSorter::new(handle.clone(), &manifest, Variant::Optimized).unwrap();
    assert!(hier.tile() <= 1 << 16, "tile must come from the fixture menu");
    let mut ours = input.clone();
    let stats = hier.sort(&mut ours).unwrap();
    assert_eq!(ours, oracle, "hierarchical vs oracle");
    assert!(stats.tiles >= 2, "{stats:?}");
    assert!(stats.device_dispatches >= 1, "{stats:?}");

    // Hybrid: device merge ladder + CPU tail.
    let hybrid = HybridSorter::with_chunk(handle.clone(), &manifest, Variant::Optimized, 1 << 16)
        .unwrap();
    let mut ours = input.clone();
    hybrid.sort(&mut ours).unwrap();
    assert_eq!(ours, oracle, "hybrid vs oracle");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&gen_dir);
}

/// Hierarchical correctness across every input distribution and awkward
/// lengths (empty, single, tile-aligned, ragged).
#[test]
fn hierarchical_all_distributions_and_ragged_lengths() {
    let Some(fixture) = fixture_dir() else { return };
    let (handle, manifest) = spawn_device_host(&fixture).unwrap();
    let sorter = HierarchicalSorter::new(handle.clone(), &manifest, Variant::Optimized).unwrap();
    let tile = sorter.tile();
    let mut gen = Generator::new(0x7135);
    for dist in Distribution::ALL {
        let orig = gen.u32s(2 * tile + 5, dist);
        let mut v = orig.clone();
        sorter.sort(&mut v).unwrap();
        assert!(is_sorted(&v), "{}", dist.name());
        assert!(same_multiset(&orig, &v), "{}", dist.name());
    }
    for n in [0usize, 1, 2, tile - 1, tile, tile + 1, 3 * tile + 917] {
        let orig = gen.u32s(n, Distribution::DupHeavy);
        let mut ours = orig.clone();
        sorter.sort(&mut ours).unwrap();
        let mut want = orig;
        quicksort(&mut want);
        assert_eq!(ours, want, "n={n}");
    }
    handle.shutdown();
}

/// The hierarchical mega-sort with the comparator ISA pinned to the
/// portable chunked kernels (always available, never the implicit
/// `Auto` choice): the ISA must be invisible in the output — bit-exact
/// with the quicksort oracle through tiling, device dispatch, and the
/// loser-tree merge.
#[test]
fn hierarchical_mega_sort_with_portable_kernels() {
    let Some(fixture) = fixture_dir() else { return };
    use bitonic_tpu::runtime::{spawn_device_host_with, PlanConfig};
    use bitonic_tpu::sort::{KernelChoice, KernelIsa};
    let portable = PlanConfig {
        kernel: KernelChoice::Fixed(KernelIsa::Portable),
        ..Default::default()
    };
    let (handle, manifest) = spawn_device_host_with(
        &fixture,
        HostConfig { plan: portable.into(), ..Default::default() },
    )
    .unwrap();
    let sorter = HierarchicalSorter::new(handle.clone(), &manifest, Variant::Optimized).unwrap();
    let tile = sorter.tile();
    let mut gen = Generator::new(0x51D);
    let orig = gen.u32s(2 * tile + 13, Distribution::DupHeavy);
    let mut ours = orig.clone();
    let stats = sorter.sort(&mut ours).unwrap();
    assert!(stats.device_dispatches >= 1, "{stats:?}");
    let mut want = orig;
    quicksort(&mut want);
    assert_eq!(ours, want, "portable-ISA hierarchical vs oracle");
    handle.shutdown();
}

/// The parallel splitter merge must be invisible at the sorter level:
/// the same ragged mega input sorted with the serial loser tree
/// (merge_threads = 1) and with the splitter-partitioned parallel merge
/// (merge_threads = 4, `sort::pmerge`) yields identical bytes, and the
/// stats say which merge actually ran.
#[test]
fn hierarchical_parallel_merge_matches_serial_bitwise() {
    let Some(fixture) = fixture_dir() else { return };
    let (handle, manifest) = spawn_device_host(&fixture).unwrap();
    let serial =
        HierarchicalSorter::new(handle.clone(), &manifest, Variant::Optimized).unwrap();
    let parallel = HierarchicalSorter::new(handle.clone(), &manifest, Variant::Optimized)
        .unwrap()
        .with_merge_threads(4);
    let tile = serial.tile();
    let mut gen = Generator::new(0x9143);
    for dist in [Distribution::Uniform, Distribution::DupHeavy, Distribution::Sorted] {
        let orig = gen.u32s(3 * tile + 917, dist);
        let mut a = orig.clone();
        let sa = serial.sort(&mut a).unwrap();
        let mut b = orig.clone();
        let sb = parallel.sort(&mut b).unwrap();
        assert_eq!(a, b, "parallel merge diverged on {}", dist.name());
        assert_eq!(sa.merge_parts, 0, "serial path must not report buckets: {sa:?}");
        assert!(sb.merge_parts > 1, "parallel path must bucket: {sb:?}");
        assert_eq!(sb.merge_threads, 4, "{sb:?}");
    }
    handle.shutdown();
}

/// Merged discovery end to end: a primary dir plus its `generated/`
/// subdir are served as one menu by `spawn_discovered`, and classes
/// from both sides execute.
#[test]
fn discovery_merges_generated_dir_into_the_menu() {
    let primary = temp_dir("discover");
    generate_artifacts(&primary, &[GenSpec::sort(1 << 10, 2, Dtype::U32, false)]).unwrap();
    generate_artifacts(
        &primary.join("generated"),
        &[GenSpec::sort(1 << 11, 1, Dtype::U32, false)],
    )
    .unwrap();
    let (handle, manifest) =
        spawn_device_host_discovered(&primary, HostConfig::default()).unwrap();
    // Both menus present…
    let small = manifest.find(Variant::Optimized, 2, 1 << 10, Dtype::U32, false);
    let big = manifest.find(Variant::Optimized, 1, 1 << 11, Dtype::U32, false);
    assert!(small.is_some() && big.is_some(), "merged menu incomplete");
    // …and the merged-in class actually executes through the registry.
    let mut gen = Generator::new(3);
    let rows = gen.u32s(1 << 11, Distribution::Uniform);
    let got = handle.sort_u32(Key::of(big.unwrap()), rows.clone()).unwrap();
    let mut want = rows;
    want.sort_unstable();
    assert_eq!(got, want);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&primary);
}
