//! Fault-injection tests for the TCP front-end: misbehaving clients —
//! mid-request and mid-response disconnects, stalled readers, and
//! admission floods — must be absorbed without wedging a worker, and
//! the very next well-behaved request must succeed. Each scenario also
//! checks that the failure landed in the right [`NetStats`] /
//! `ServiceStats` counter, so operators can see it.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitonic_tpu::coordinator::net::{Frame, NetClient, NetServer, NetServerConfig, SortReply};
use bitonic_tpu::coordinator::{BatchSorter, Service, ServiceConfig};
use bitonic_tpu::sort::bitonic_sort;

/// CPU mock with an optional per-batch delay (holds admission permits
/// long enough for floods to actually collide with the gate).
struct SlowMock {
    batch: usize,
    n: usize,
    delay: Duration,
}

impl BatchSorter for SlowMock {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        for r in rows.chunks_mut(self.n) {
            bitonic_sort(r);
        }
        Ok(rows)
    }
}

fn serve_with(
    classes: Vec<(usize, usize, Duration)>,
    service: ServiceConfig,
    net: NetServerConfig,
) -> (NetServer, Arc<Service>) {
    let sorters = classes
        .into_iter()
        .map(|(batch, n, delay)| {
            Arc::new(SlowMock { batch, n, delay }) as Arc<dyn BatchSorter>
        })
        .collect();
    let svc = Service::new(sorters, service);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", net).unwrap();
    (server, svc)
}

fn teardown(mut server: NetServer, svc: Arc<Service>) {
    server.request_shutdown();
    server.shutdown();
    svc.shutdown();
}

/// Poll `cond` until it holds or `deadline` passes. The counters these
/// tests watch are bumped by server threads, so assertions must wait,
/// not sample once.
fn eventually(deadline: Duration, what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn assert_next_request_succeeds(server: &NetServer) {
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    match client.sort(999, vec![4u32, 2, 6, 1], false, None).unwrap() {
        SortReply::Sorted { keys, .. } => assert_eq!(keys, vec![1, 2, 4, 6]),
        other => panic!("follow-up request failed: {other:?}"),
    }
}

#[test]
fn disconnect_mid_request_is_counted_and_does_not_wedge_the_server() {
    let (server, svc) = serve_with(
        vec![(4, 64, Duration::ZERO)],
        ServiceConfig::default(),
        NetServerConfig::default(),
    );
    {
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        // A length prefix promising a 64-byte frame, then only 10 bytes
        // of it — the connection dies mid-frame.
        stream.write_all(&64u32.to_le_bytes()).unwrap();
        stream.write_all(&[0u8; 10]).unwrap();
        stream.flush().unwrap();
    } // drop = RST/FIN with a partial frame buffered server-side
    eventually(Duration::from_secs(20), "disconnect counter", || {
        server.stats().disconnects.get() >= 1
    });
    assert_next_request_succeeds(&server);
    teardown(server, svc);
}

#[test]
fn disconnect_mid_response_is_absorbed() {
    // The delay keeps the batch in flight while the client walks away,
    // so the server's response write lands on a dead connection.
    let (server, svc) = serve_with(
        vec![(1, 64, Duration::from_millis(50))],
        ServiceConfig::default(),
        NetServerConfig::default(),
    );
    {
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        let frame = Frame::Sort { id: 1, descending: false, slo_us: 0, keys: vec![3, 1, 2] };
        stream.write_all(&frame.encode()).unwrap();
        stream.flush().unwrap();
    } // drop before the 50ms batch completes
    // The request still runs to completion service-side…
    eventually(Duration::from_secs(20), "batch completion", || {
        svc.stats().latency.count() >= 1
    });
    // …and the server survives the failed response write.
    assert_next_request_succeeds(&server);
    teardown(server, svc);
}

#[test]
fn stalled_reader_trips_the_write_timeout() {
    // Big rows + a tiny write timeout: a client that floods requests but
    // never reads responses must get its connection cut, not pin a
    // server thread forever.
    let (server, svc) = serve_with(
        vec![(1, 65536, Duration::ZERO)],
        ServiceConfig::default(),
        NetServerConfig {
            write_timeout: Duration::from_millis(200),
            ..NetServerConfig::default()
        },
    );
    let addr = server.local_addr();
    let flood = std::thread::spawn(move || {
        let Ok(mut stream) = TcpStream::connect(addr) else { return };
        let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
        // ~32 responses × 256KB ≫ any default socket buffering. Writes
        // start failing once the server cuts the connection; that is the
        // point, so errors just end the flood.
        for id in 0..32u64 {
            let keys: Vec<u32> = (0..65536u32).rev().collect();
            let frame = Frame::Sort { id, descending: false, slo_us: 0, keys };
            if stream.write_all(&frame.encode()).is_err() {
                break;
            }
        }
        // Never read; never close until the timeout fires server-side.
        std::thread::sleep(Duration::from_secs(20));
    });
    eventually(Duration::from_secs(30), "write timeout counter", || {
        server.stats().write_timeouts.get() >= 1
    });
    assert_next_request_succeeds(&server);
    teardown(server, svc);
    // The flood thread sleeps out its 20s on purpose; don't wait for it.
    drop(flood);
}

#[test]
fn flood_past_the_admission_gate_sheds_and_recovers() {
    let (server, svc) = serve_with(
        vec![(1, 256, Duration::from_millis(30))],
        ServiceConfig { max_in_flight: 2, ..ServiceConfig::default() },
        NetServerConfig::default(),
    );
    let addr = server.local_addr();
    let workers: Vec<_> = (0..16u64)
        .map(|id| {
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                let keys: Vec<u32> = (0..256u32).rev().collect();
                client.sort(id, keys, false, None).unwrap()
            })
        })
        .collect();
    let replies: Vec<SortReply> = workers.into_iter().map(|h| h.join().unwrap()).collect();
    let sheds = replies
        .iter()
        .filter(|r| matches!(r, SortReply::Shed { .. }))
        .count();
    let sorted = replies
        .iter()
        .filter(|r| {
            matches!(r, SortReply::Sorted { keys, .. } if keys.windows(2).all(|w| w[0] <= w[1]))
        })
        .count();
    assert_eq!(sheds + sorted, 16, "unexpected reply kind in {replies:?}");
    assert!(sheds >= 1, "16-way flood against max_in_flight=2 never shed");
    assert!(sorted >= 1, "every request shed — the gate admitted nothing");
    // The shed landed in both the aggregate and the per-class counters,
    // and on the transport's own tally.
    let st = svc.stats();
    assert_eq!(st.shed.get(), sheds as u64);
    assert_eq!(st.classes[0].shed.get(), sheds as u64);
    assert_eq!(server.stats().sheds.get(), sheds as u64);
    // No wedged worker: a well-behaved request sails through.
    assert_next_request_succeeds(&server);
    teardown(server, svc);
}
