//! Property-based tests of the coordinator invariants (DESIGN.md §6.5),
//! using the in-repo `util::prop` framework with CPU-mock backends so the
//! properties run without artifacts.

use std::sync::Arc;

use bitonic_tpu::coordinator::{
    BatchSorter, Service, ServiceConfig, SortRequest,
};
use bitonic_tpu::sort::bitonic_sort;
use bitonic_tpu::util::prop::{check_with, Config, Strategy};
use bitonic_tpu::workload::rng::Pcg32;

/// CPU mock backend.
struct Mock {
    batch: usize,
    n: usize,
}

impl BatchSorter for Mock {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
        for r in rows.chunks_mut(self.n) {
            bitonic_sort(r);
        }
        Ok(rows)
    }
}

fn service(classes: &[(usize, usize)]) -> Arc<Service> {
    Service::new(
        classes
            .iter()
            .map(|&(batch, n)| Arc::new(Mock { batch, n }) as Arc<dyn BatchSorter>)
            .collect(),
        ServiceConfig::default(),
    )
}

/// A randomized request workload: lengths, values, directions.
#[derive(Clone, Debug)]
struct Workload {
    requests: Vec<(Vec<u32>, bool)>,
}

struct WorkloadStrategy {
    max_requests: usize,
    max_len: usize,
}

impl Strategy for WorkloadStrategy {
    type Value = Workload;
    fn sample(&self, rng: &mut Pcg32) -> Workload {
        let count = 1 + rng.next_below(self.max_requests as u32) as usize;
        let requests = (0..count)
            .map(|_| {
                let len = rng.next_below(self.max_len as u32 + 1) as usize;
                let keys = (0..len).map(|_| rng.next_u32()).collect();
                let descending = rng.next_below(4) == 0;
                (keys, descending)
            })
            .collect();
        Workload { requests }
    }
    fn shrink(&self, v: &Workload) -> Vec<Workload> {
        let mut out = Vec::new();
        if v.requests.len() > 1 {
            out.push(Workload {
                requests: v.requests[..v.requests.len() / 2].to_vec(),
            });
            out.push(Workload {
                requests: v.requests[v.requests.len() / 2..].to_vec(),
            });
        }
        // Shrink the longest request.
        if let Some(idx) = v
            .requests
            .iter()
            .enumerate()
            .max_by_key(|(_, (k, _))| k.len())
            .map(|(i, _)| i)
        {
            if !v.requests[idx].0.is_empty() {
                let mut w = v.clone();
                let half = w.requests[idx].0.len() / 2;
                w.requests[idx].0.truncate(half);
                out.push(w);
            }
        }
        out
    }
}

#[test]
fn every_request_answered_exactly_once_and_sorted() {
    let strategy = WorkloadStrategy {
        max_requests: 40,
        max_len: 700,
    };
    check_with(
        Config {
            cases: 24,
            ..Config::default()
        },
        &strategy,
        |w| {
            let svc = service(&[(4, 64), (8, 256)]);
            let rxs: Vec<_> = w
                .requests
                .iter()
                .enumerate()
                .map(|(i, (keys, desc))| {
                    svc.submit(SortRequest {
                        id: i as u64,
                        keys: keys.clone(),
                        descending: *desc,
                        slo: None,
                    })
                })
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let rx = rx.map_err(|_| format!("request {i} shed unexpectedly"))?;
                let resp = rx
                    .recv()
                    .map_err(|_| format!("request {i} never answered"))?;
                if resp.id != i as u64 {
                    return Err(format!("id mismatch: got {} want {i}", resp.id));
                }
                let (keys, desc) = &w.requests[i];
                if resp.keys.len() != keys.len() {
                    return Err(format!(
                        "request {i}: length {} != {}",
                        resp.keys.len(),
                        keys.len()
                    ));
                }
                let mut want = keys.clone();
                want.sort_unstable();
                if *desc {
                    want.reverse();
                }
                if resp.keys != want {
                    return Err(format!("request {i}: wrong output"));
                }
                // Exactly once: a second recv must fail (sender dropped).
                if rx.recv().is_ok() {
                    return Err(format!("request {i} answered twice"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn invariants_hold_with_shared_worker_pool() {
    // The exactly-once/sorted invariants must survive the work-stealing
    // scheduler when workers outnumber size classes (threads > classes).
    let strategy = WorkloadStrategy {
        max_requests: 40,
        max_len: 700,
    };
    check_with(
        Config {
            cases: 12,
            ..Config::default()
        },
        &strategy,
        |w| {
            let svc = Service::new(
                vec![
                    Arc::new(Mock { batch: 4, n: 64 }) as Arc<dyn BatchSorter>,
                    Arc::new(Mock { batch: 8, n: 256 }) as Arc<dyn BatchSorter>,
                ],
                ServiceConfig {
                    threads: 4,
                    ..ServiceConfig::default()
                },
            );
            let rxs: Vec<_> = w
                .requests
                .iter()
                .enumerate()
                .map(|(i, (keys, desc))| {
                    svc.submit(SortRequest {
                        id: i as u64,
                        keys: keys.clone(),
                        descending: *desc,
                        slo: None,
                    })
                })
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let rx = rx.map_err(|_| format!("request {i} shed unexpectedly"))?;
                let resp = rx
                    .recv()
                    .map_err(|_| format!("request {i} never answered"))?;
                let (keys, desc) = &w.requests[i];
                let mut want = keys.clone();
                want.sort_unstable();
                if *desc {
                    want.reverse();
                }
                if resp.keys != want {
                    return Err(format!("request {i}: wrong output"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn admission_gate_never_exceeded_and_sheds_only_when_full() {
    struct CapacityStrategy;
    impl Strategy for CapacityStrategy {
        type Value = (usize, usize);
        fn sample(&self, rng: &mut Pcg32) -> (usize, usize) {
            (
                1 + rng.next_below(8) as usize,   // capacity
                1 + rng.next_below(64) as usize,  // burst size
            )
        }
    }
    check_with(
        Config {
            cases: 32,
            ..Config::default()
        },
        &CapacityStrategy,
        |&(capacity, burst)| {
            let svc = Service::new(
                vec![Arc::new(Mock { batch: 4, n: 64 }) as Arc<dyn BatchSorter>],
                ServiceConfig {
                    max_in_flight: capacity,
                    ..ServiceConfig::default()
                },
            );
            let mut receivers = Vec::new();
            let mut shed = 0usize;
            for i in 0..burst {
                match svc.submit(SortRequest::new(i as u64, vec![2, 1])) {
                    Ok(rx) => receivers.push(rx),
                    Err(_) => shed += 1,
                }
            }
            // Shedding may only happen once in-flight hit capacity.
            if shed > 0 && receivers.len() < capacity.min(burst) {
                return Err(format!(
                    "shed {shed} while only {} in flight (cap {capacity})",
                    receivers.len()
                ));
            }
            for rx in receivers {
                rx.recv().map_err(|_| "dropped response".to_string())?;
            }
            Ok(())
        },
    );
}

#[test]
fn batches_never_mix_size_classes() {
    // Indirect but strong check: with two classes whose mocks tag outputs,
    // a mixed batch would corrupt row lengths and fail the sort check.
    struct TaggingMock {
        batch: usize,
        n: usize,
    }
    impl BatchSorter for TaggingMock {
        fn shape(&self) -> (usize, usize) {
            (self.batch, self.n)
        }
        fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
            bitonic_tpu::ensure!(
                rows.len() == self.batch * self.n,
                "batch shape violated: {} != {}x{}",
                rows.len(),
                self.batch,
                self.n
            );
            for r in rows.chunks_mut(self.n) {
                bitonic_sort(r);
            }
            Ok(rows)
        }
    }
    let svc = Service::new(
        vec![
            Arc::new(TaggingMock { batch: 2, n: 32 }) as Arc<dyn BatchSorter>,
            Arc::new(TaggingMock { batch: 8, n: 512 }) as Arc<dyn BatchSorter>,
        ],
        ServiceConfig::default(),
    );
    let strategy = WorkloadStrategy {
        max_requests: 60,
        max_len: 512,
    };
    check_with(
        Config {
            cases: 16,
            ..Config::default()
        },
        &strategy,
        |w| {
            let rxs: Vec<_> = w
                .requests
                .iter()
                .enumerate()
                .map(|(i, (keys, _))| svc.submit(SortRequest::new(i as u64, keys.clone())))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let rx = rx.map_err(|_| "shed".to_string())?;
                let resp = rx.recv().map_err(|_| format!("request {i} dropped"))?;
                if !resp.keys.windows(2).all(|p| p[0] <= p[1]) {
                    return Err(format!("request {i} unsorted"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn responses_preserve_multisets_under_concurrency() {
    let svc = service(&[(8, 128)]);
    let strategy = WorkloadStrategy {
        max_requests: 32,
        max_len: 128,
    };
    check_with(
        Config {
            cases: 12,
            ..Config::default()
        },
        &strategy,
        |w| {
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (i, (keys, desc)) in w.requests.iter().enumerate() {
                    let svc = &svc;
                    handles.push(scope.spawn(move || {
                        let resp = svc
                            .sort_blocking(SortRequest {
                                id: i as u64,
                                keys: keys.clone(),
                                descending: *desc,
                                slo: None,
                            })
                            .map_err(|_| "shed".to_string())?;
                        let mut want = keys.clone();
                        want.sort_unstable();
                        if *desc {
                            want.reverse();
                        }
                        if resp.keys == want {
                            Ok(())
                        } else {
                            Err(format!("request {i} corrupted"))
                        }
                    }));
                }
                for h in handles {
                    h.join().map_err(|_| "panicked".to_string())??;
                }
                Ok(())
            })
        },
    );
}
