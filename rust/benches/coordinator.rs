//! L3 coordinator benchmarks: batching benefit, coordinator overhead over
//! a raw backend call, shed behaviour under overload, and the
//! plan/execute split's row-parallel executor sweep — the numbers the
//! §Perf pass optimizes (DESIGN.md §7, ROADMAP "parallelise the native
//! executor" measurement ask).

use std::sync::Arc;
use std::time::{Duration, Instant};

use bitonic_tpu::bench::Bench;
use bitonic_tpu::coordinator::{
    BatchSorter, BatcherConfig, Service, ServiceConfig, SortRequest,
};
use bitonic_tpu::runtime::{default_artifacts_dir, Key, PlanConfig, Registry};
use bitonic_tpu::sort::bitonic_sort;
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::util::table::{fmt_ms, Table};
use bitonic_tpu::util::threadpool::ThreadPool;
use bitonic_tpu::workload::{Distribution, Generator};

struct Mock {
    batch: usize,
    n: usize,
    /// Simulated per-execution device latency (models PJRT dispatch).
    exec_cost: Duration,
}

impl BatchSorter for Mock {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, mut rows: Vec<u32>) -> bitonic_tpu::Result<Vec<u32>> {
        if !self.exec_cost.is_zero() {
            std::thread::sleep(self.exec_cost);
        }
        for r in rows.chunks_mut(self.n) {
            bitonic_sort(r);
        }
        Ok(rows)
    }
}

fn main() {
    let bench = Bench::quick();
    let mut gen = Generator::new(0xC00D);

    // --- 1. coordinator overhead: service vs direct backend call ---------
    // Same total work (64 requests of one full row each), batch=1 so the
    // batcher adds no benefit — the difference IS the coordinator tax.
    println!("== coordinator overhead (batch=1, n=4096, 64 requests) ==");
    let n = 4096;
    let direct_mock = Mock { batch: 1, n, exec_cost: Duration::ZERO };
    let direct = bench.run_with_setup(
        "direct",
        || {
            (0..64)
                .map(|_| gen.u32s(n, Distribution::Uniform))
                .collect::<Vec<_>>()
        },
        |inputs| {
            for keys in inputs {
                let mut padded = keys;
                padded.resize(n, u32::MAX);
                let _ = direct_mock.sort_rows(padded).unwrap();
            }
        },
    );
    let svc = Service::new(
        vec![Arc::new(Mock { batch: 1, n, exec_cost: Duration::ZERO }) as Arc<dyn BatchSorter>],
        ServiceConfig {
            batcher: BatcherConfig {
                max_wait: Duration::from_micros(50),
                max_rows: 1,
                ..BatcherConfig::default()
            },
            ..ServiceConfig::default()
        },
    );
    let via_service = bench.run_with_setup(
        "service",
        || {
            (0..64)
                .map(|_| gen.u32s(n, Distribution::Uniform))
                .collect::<Vec<_>>()
        },
        |inputs| {
            let rxs: Vec<_> = inputs
                .into_iter()
                .enumerate()
                .map(|(i, keys)| svc.submit(SortRequest::new(i as u64, keys)).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
        },
    );
    let overhead =
        (via_service.median_ms() - direct.median_ms()) / direct.median_ms() * 100.0;
    println!("  direct : {}", direct.summary());
    println!("  service: {}", via_service.summary());
    println!("  overhead: {overhead:+.1}% (target <5% — DESIGN.md §7)\n");

    // --- 2. batching benefit under simulated dispatch cost ---------------
    // With a fixed per-execution cost (PJRT dispatch ≈ 100µs class),
    // batching B requests into one execution amortises it.
    println!("== batching benefit (exec cost 500µs, n=1024, 64 requests) ==");
    let mut t = Table::new(vec!["device batch B", "wall ms", "throughput req/s"]);
    for b in [1usize, 2, 4, 8, 16] {
        let svc = Service::new(
            vec![Arc::new(Mock {
                batch: b,
                n: 1024,
                exec_cost: Duration::from_micros(500),
            }) as Arc<dyn BatchSorter>],
            ServiceConfig {
                batcher: BatcherConfig {
                    max_wait: Duration::from_millis(1),
                    max_rows: b,
                    ..BatcherConfig::default()
                },
                ..ServiceConfig::default()
            },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                svc.submit(SortRequest::new(i, gen.u32s(1024, Distribution::Uniform)))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed();
        t.row(vec![
            b.to_string(),
            fmt_ms(wall.as_secs_f64() * 1e3),
            format!("{:.0}", 64.0 / wall.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());
    println!("→ dynamic batching amortises fixed dispatch cost ~linearly in B.\n");

    // --- 3. overload: shedding keeps latency bounded ----------------------
    println!("== overload behaviour (capacity 32, offered 500) ==");
    let svc = Service::new(
        vec![Arc::new(Mock {
            batch: 8,
            n: 1024,
            exec_cost: Duration::from_micros(200),
        }) as Arc<dyn BatchSorter>],
        ServiceConfig {
            max_in_flight: 32,
            ..ServiceConfig::default()
        },
    );
    let t0 = Instant::now();
    let mut accepted = Vec::new();
    let mut shed = 0;
    for i in 0..500u64 {
        match svc.submit(SortRequest::new(i, gen.u32s(512, Distribution::Uniform))) {
            Ok(rx) => accepted.push(rx),
            Err(_) => shed += 1,
        }
    }
    for rx in &accepted {
        rx.recv().unwrap();
    }
    println!(
        "  accepted {} shed {shed} in {} — p99 latency {}",
        accepted.len(),
        fmt_ms(t0.elapsed().as_secs_f64() * 1e3),
        fmt_ms(svc.stats().latency.quantile_ns(0.99) as f64 / 1e6),
    );
    println!("  (shed>0 and bounded queue ⇒ latency stays flat under overload)\n");

    // --- 4. plan/execute split: row-parallel executor, before/after ------
    // The real artifact path over the checked-in fixture: a serial
    // registry vs pooled registries at 2/4/8 threads, batch throughput in
    // rows/sec. This is the ROADMAP measurement ask for "parallelise the
    // native executor across rows".
    println!("== row-parallel executor (fixture artifacts, rows/sec) ==");
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("  (no artifacts at {dir:?} — skipping executor sweep)");
        return;
    }
    // Largest-batch artifact of the optimized variant (size_classes
    // already filters to ascending-u32 sort entries), B≥8 in the fixture.
    let probe = Registry::open(&dir).expect("open artifacts");
    let meta = probe
        .manifest()
        .size_classes(Variant::Optimized)
        .into_iter()
        .max_by_key(|m| m.batch)
        .expect("no optimized u32 sort artifact in fixture")
        .clone();
    let (b, n) = (meta.batch, meta.n);
    println!("  artifact: {} (B={b}, N={n})", meta.name);
    let mut t = Table::new(vec!["pool threads", "ms / batch", "rows/sec", "speedup"]);
    let mut serial_ms = f64::NAN;
    for threads in [1usize, 2, 4, 8] {
        // threads=1 is the serial baseline: no pool at all.
        let pool = if threads > 1 {
            Some(Arc::new(ThreadPool::new(threads, 2 * threads)))
        } else {
            None
        };
        let registry =
            Registry::open_with_pool(&dir, pool, PlanConfig::default()).expect("open artifacts");
        let exe = registry.get(Key::of(&meta)).expect("compile artifact");
        let m = bench.run_with_setup(
            &format!("threads={threads}"),
            || gen.u32s(b * n, Distribution::Uniform),
            |rows| {
                let _ = exe.sort_u32(rows).unwrap();
            },
        );
        let ms = m.median_ms();
        if threads == 1 {
            serial_ms = ms;
        }
        t.row(vec![
            threads.to_string(),
            fmt_ms(ms),
            format!("{:.0}", b as f64 / (ms / 1e3)),
            format!("{:.2}x", serial_ms / ms),
        ]);
    }
    println!("{}", t.render());
    println!("→ the ExecutionPlan walk is identical; only the row dispatch changes —");
    println!("  pool threads >1 must beat the serial baseline on B≥8 batches.");
}
