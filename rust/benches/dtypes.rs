//! Key-type study (DESIGN.md E8 — the paper's §6 future work: "64-bit
//! integer, 32-bit float, 64-bit double"): CPU measurements for all four
//! key types, simulator predictions for the byte-width effect, and the
//! measured f32/i32 artifacts.

use bitonic_tpu::bench::Bench;
use bitonic_tpu::runtime::{spawn_device_host, Dtype, Key};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{bitonic_sort, quicksort};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let bench = Bench::quick();
    let mut gen = Generator::new(0xD7E5);
    let n = 1 << 20;

    // --- CPU: four key types ---------------------------------------------
    println!("== CPU sorts by key type, n = {} uniform ==", fmt_size(n));
    let mut t = Table::new(vec!["key type", "quicksort ms", "bitonic ms", "bitonic/quick"]);
    let q32 = bench
        .run_with_setup("q", || gen.u32s(n, Distribution::Uniform), |mut v| quicksort(&mut v))
        .median_ms();
    let b32 = bench
        .run_with_setup("b", || gen.u32s(n, Distribution::Uniform), |mut v| bitonic_sort(&mut v))
        .median_ms();
    t.row(vec!["u32".into(), fmt_ms(q32), fmt_ms(b32), format!("{:.1}x", b32 / q32)]);
    let q64 = bench
        .run_with_setup("q", || gen.u64s(n, Distribution::Uniform), |mut v| quicksort(&mut v))
        .median_ms();
    let b64 = bench
        .run_with_setup("b", || gen.u64s(n, Distribution::Uniform), |mut v| bitonic_sort(&mut v))
        .median_ms();
    t.row(vec!["u64".into(), fmt_ms(q64), fmt_ms(b64), format!("{:.1}x", b64 / q64)]);
    let qf = bench
        .run_with_setup("q", || gen.f32s(n, Distribution::Uniform), |mut v| quicksort(&mut v))
        .median_ms();
    let bf = bench
        .run_with_setup("b", || gen.f32s(n, Distribution::Uniform), |mut v| bitonic_sort(&mut v))
        .median_ms();
    t.row(vec!["f32".into(), fmt_ms(qf), fmt_ms(bf), format!("{:.1}x", bf / qf)]);
    let qd = bench
        .run_with_setup("q", || gen.f64s(n, Distribution::Uniform), |mut v| quicksort(&mut v))
        .median_ms();
    let bd = bench
        .run_with_setup("b", || gen.f64s(n, Distribution::Uniform), |mut v| bitonic_sort(&mut v))
        .median_ms();
    t.row(vec!["f64".into(), fmt_ms(qd), fmt_ms(bd), format!("{:.1}x", bd / qd)]);
    println!("{}", t.render());

    // --- simulator: byte-width effect on the GPU --------------------------
    println!("== simulated GPU effect of key width (optimized, n = 16M) ==");
    let cal = calibrate_from_table1();
    let mut t = Table::new(vec!["key bytes", "launches", "ms (sim)", "vs 4B"]);
    let base = simulate(&cal.device, Variant::Optimized, 16 << 20, 4).total_ms();
    for bytes in [4usize, 8] {
        let r = simulate(&cal.device, Variant::Optimized, 16 << 20, bytes);
        t.row(vec![
            bytes.to_string(),
            r.launches.to_string(),
            fmt_ms(r.total_ms()),
            format!("{:.2}x", r.total_ms() / base),
        ]);
    }
    println!("{}", t.render());
    println!("→ 8-byte keys double bandwidth *and* halve the shared tile (more launches).\n");

    // --- measured artifacts: i32 / f32 ------------------------------------
    println!("== measured non-u32 artifacts (native-CPU executor) ==");
    match spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir()) {
        Ok((handle, manifest)) => {
            for meta in manifest
                .entries
                .iter()
                .filter(|m| m.dtype != Dtype::U32 && !m.descending)
            {
                let key = Key::of(meta);
                let rows_f: Vec<f32>;
                let rows_i: Vec<i32>;
                let ms = match meta.dtype {
                    Dtype::F32 => {
                        rows_f = gen.f32s(meta.batch * meta.n, Distribution::Uniform);
                        let _ = handle.sort_f32(key, rows_f.clone()).unwrap();
                        bench
                            .run_with_setup(
                                "f32",
                                || rows_f.clone(),
                                |r| {
                                    let _ = handle.sort_f32(key, r).unwrap();
                                },
                            )
                            .median_ms()
                    }
                    Dtype::I32 => {
                        rows_i = gen
                            .u32s(meta.batch * meta.n, Distribution::Uniform)
                            .into_iter()
                            .map(|x| x as i32)
                            .collect();
                        let _ = handle.sort_i32(key, rows_i.clone()).unwrap();
                        bench
                            .run_with_setup(
                                "i32",
                                || rows_i.clone(),
                                |r| {
                                    let _ = handle.sort_i32(key, r).unwrap();
                                },
                            )
                            .median_ms()
                    }
                    Dtype::U32 => unreachable!(),
                };
                println!("  {:<44} {} ms", meta.name, fmt_ms(ms));
            }
        }
        Err(e) => println!("   (skipped: {e:#})"),
    }
}
