//! Key-type study (DESIGN.md E8 — the paper's §6 future work: "64-bit
//! integer, 32-bit float, 64-bit double"): CPU measurements for all four
//! key types, simulator predictions for the byte-width effect, and the
//! measured f32/i32 artifacts — all appended to the unified bench
//! trajectory (`BENCH_trajectory.json`).

use bitonic_tpu::bench::{Bench, BenchRecord, Measurement, Trajectory};
use bitonic_tpu::runtime::{spawn_device_host, Dtype, Key};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{bitonic_sort, quicksort, SortKey};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let bench = Bench::quick();
    let mut gen = Generator::new(0xD7E5);
    let mut records: Vec<BenchRecord> = Vec::new();
    let n = 1 << 20;

    // --- CPU: four key types ---------------------------------------------
    println!("== CPU sorts by key type, n = {} uniform ==", fmt_size(n));
    let mut t = Table::new(vec!["key type", "quicksort ms", "bitonic ms", "bitonic/quick"]);
    let mut row = |dtype: &str, qm: Measurement, bm: Measurement| {
        let (q, b) = (qm.median_ms(), bm.median_ms());
        t.row(vec![dtype.into(), fmt_ms(q), fmt_ms(b), format!("{:.1}x", b / q)]);
        records.push(BenchRecord::new("dtypes", "quicksort", "uniform", dtype, n).with_timing(&qm));
        records.push(
            BenchRecord::new("dtypes", "bitonic-scalar", "uniform", dtype, n).with_timing(&bm),
        );
    };
    fn pair<T: SortKey>(
        bench: &Bench,
        mut make: impl FnMut() -> Vec<T> + Clone,
    ) -> (Measurement, Measurement) {
        let mut make2 = make.clone();
        let q = bench.run_with_setup("q", &mut make, |mut v| quicksort(&mut v));
        let b = bench.run_with_setup("b", &mut make2, |mut v| bitonic_sort(&mut v));
        (q, b)
    }
    let (q, b) = pair(&bench, {
        let mut g = gen.clone();
        move || g.u32s(n, Distribution::Uniform)
    });
    row("u32", q, b);
    let (q, b) = pair(&bench, {
        let mut g = gen.clone();
        move || g.u64s(n, Distribution::Uniform)
    });
    row("u64", q, b);
    let (q, b) = pair(&bench, {
        let mut g = gen.clone();
        move || g.f32s(n, Distribution::Uniform)
    });
    row("f32", q, b);
    let (q, b) = pair(&bench, {
        let mut g = gen.clone();
        move || g.f64s(n, Distribution::Uniform)
    });
    row("f64", q, b);
    drop(row);
    println!("{}", t.render());

    // --- simulator: byte-width effect on the GPU --------------------------
    println!("== simulated GPU effect of key width (optimized, n = 16M) ==");
    let cal = calibrate_from_table1();
    let mut t = Table::new(vec!["key bytes", "launches", "ms (sim)", "vs 4B"]);
    let base = simulate(&cal.device, Variant::Optimized, 16 << 20, 4).total_ms();
    for bytes in [4usize, 8] {
        let r = simulate(&cal.device, Variant::Optimized, 16 << 20, bytes);
        t.row(vec![
            bytes.to_string(),
            r.launches.to_string(),
            fmt_ms(r.total_ms()),
            format!("{:.2}x", r.total_ms() / base),
        ]);
    }
    println!("{}", t.render());
    println!("→ 8-byte keys double bandwidth *and* halve the shared tile (more launches).\n");

    // --- measured artifacts: i32 / f32 ------------------------------------
    println!("== measured non-u32 artifacts (native-CPU executor) ==");
    match spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir()) {
        Ok((handle, manifest)) => {
            for meta in manifest
                .entries
                .iter()
                .filter(|m| m.dtype != Dtype::U32 && !m.descending)
            {
                let key = Key::of(meta);
                let rows_f: Vec<f32>;
                let rows_i: Vec<i32>;
                let (dtype, m) = match meta.dtype {
                    Dtype::F32 => {
                        rows_f = gen.f32s(meta.batch * meta.n, Distribution::Uniform);
                        let _ = handle.sort_f32(key, rows_f.clone()).unwrap();
                        let m = bench.run_with_setup(
                            "f32",
                            || rows_f.clone(),
                            |r| {
                                let _ = handle.sort_f32(key, r).unwrap();
                            },
                        );
                        ("f32", m)
                    }
                    Dtype::I32 => {
                        rows_i = gen
                            .u32s(meta.batch * meta.n, Distribution::Uniform)
                            .into_iter()
                            .map(|x| x as i32)
                            .collect();
                        let _ = handle.sort_i32(key, rows_i.clone()).unwrap();
                        let m = bench.run_with_setup(
                            "i32",
                            || rows_i.clone(),
                            |r| {
                                let _ = handle.sort_i32(key, r).unwrap();
                            },
                        );
                        ("i32", m)
                    }
                    Dtype::U32 => unreachable!(),
                };
                println!("  {:<44} {} ms", meta.name, fmt_ms(m.median_ms()));
                records.push(
                    BenchRecord::new("dtypes", "bitonic-executor", "uniform", dtype, meta.n)
                        .with_batch(meta.batch)
                        .with_timing(&m)
                        .with_extra("artifact", meta.name.as_str())
                        .with_extra("variant", meta.variant.name()),
                );
            }
        }
        Err(e) => println!("   (skipped: {e:#})"),
    }

    Trajectory::append_default_or_exit(records);
}
