//! CPU substrate shoot-out (DESIGN.md E1/E2/E9): every from-scratch sort
//! vs the std library across distributions, plus the multicore bitonic
//! scaling study the paper lists as future work (§6).
//!
//! Every measurement is also appended to the unified bench trajectory
//! (`BENCH_trajectory.json`, see `bitonic_tpu::bench::record`) so the
//! numbers land next to the matrix sweep's instead of evaporating with
//! the terminal scrollback.

use bitonic_tpu::bench::{Bench, BenchRecord, Trajectory};
use bitonic_tpu::sort::{
    bitonic_sort, bitonic_sort_parallel, heapsort, mergesort, oddeven_sort, quicksort,
    radix_sort_u32,
};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let bench = Bench::quick();
    let mut gen = Generator::new(0xC0DE);
    let mut records: Vec<BenchRecord> = Vec::new();
    let n = 1 << 20;

    // --- all sorts on uniform u32 ---------------------------------------
    println!("== CPU sorts, n = {} uniform u32 ==", fmt_size(n));
    let mut t = Table::new(vec!["algorithm", "median ms", "vs std"]);
    let std_ms = bench
        .run_with_setup("std", || gen.u32s(n, Distribution::Uniform), |mut v| {
            v.sort_unstable()
        })
        .median_ms();
    // (label for the table, substrate slug for the trajectory, sort fn)
    let algos: Vec<(&str, &str, Box<dyn FnMut(Vec<u32>)>)> = vec![
        ("std sort_unstable", "std-sort", Box::new(|mut v: Vec<u32>| v.sort_unstable())),
        ("quicksort (ours)", "quicksort", Box::new(|mut v: Vec<u32>| quicksort(&mut v))),
        ("heapsort", "heap", Box::new(|mut v: Vec<u32>| heapsort(&mut v))),
        ("mergesort", "merge", Box::new(|mut v: Vec<u32>| mergesort(&mut v))),
        ("radix (LSD)", "radix", Box::new(|mut v: Vec<u32>| radix_sort_u32(&mut v))),
        ("bitonic (seq)", "bitonic-scalar", Box::new(|mut v: Vec<u32>| bitonic_sort(&mut v))),
        (
            "bitonic (4 thr)",
            "bitonic-parallel",
            Box::new(|mut v: Vec<u32>| bitonic_sort_parallel(&mut v, 4)),
        ),
    ];
    for (name, slug, mut f) in algos {
        let m = bench.run_with_setup(name, || gen.u32s(n, Distribution::Uniform), &mut f);
        t.row(vec![
            name.to_string(),
            fmt_ms(m.median_ms()),
            format!("{:.2}x", m.median_ms() / std_ms),
        ]);
        let mut r = BenchRecord::new("cpu_sorts", slug, "uniform", "u32", n).with_timing(&m);
        if slug == "bitonic-parallel" {
            r = r.with_extra("threads", 4usize);
        }
        records.push(r.with_extra("vs_std", m.median_ms() / std_ms));
    }
    println!("{}", t.render());

    // --- quicksort vs distributions (adversarial robustness) -------------
    println!("== quicksort robustness across distributions, n = 1M ==");
    let mut t = Table::new(vec!["distribution", "quick ms", "bitonic ms"]);
    for d in Distribution::ALL {
        let qm = bench.run_with_setup("q", || gen.u32s(n, d), |mut v| quicksort(&mut v));
        let bm = bench.run_with_setup("b", || gen.u32s(n, d), |mut v| bitonic_sort(&mut v));
        t.row(vec![d.name().to_string(), fmt_ms(qm.median_ms()), fmt_ms(bm.median_ms())]);
        records.push(
            BenchRecord::new("cpu_sorts", "quicksort", d.name(), "u32", n).with_timing(&qm),
        );
        records.push(
            BenchRecord::new("cpu_sorts", "bitonic-scalar", d.name(), "u32", n).with_timing(&bm),
        );
    }
    println!("{}", t.render());
    println!("→ bitonic is distribution-oblivious (data-independent network); quicksort varies.\n");

    // --- multicore bitonic scaling (paper §6 future work, E9) ------------
    println!("== multicore bitonic scaling, n = 4M (paper §6 future work) ==");
    let n = 4 << 20;
    let seq_m = bench.run_with_setup("seq", || gen.u32s(n, Distribution::Uniform), |mut v| {
        bitonic_sort(&mut v)
    });
    let seq = seq_m.median_ms();
    let mut t = Table::new(vec!["threads", "median ms", "speedup"]);
    t.row(vec!["1 (seq)".to_string(), fmt_ms(seq), "1.00x".to_string()]);
    records.push(
        BenchRecord::new("cpu_sorts", "bitonic-scalar", "uniform", "u32", n).with_timing(&seq_m),
    );
    for threads in [2usize, 4, 8, 16] {
        let m = bench.run_with_setup(
            "par",
            || gen.u32s(n, Distribution::Uniform),
            |mut v| bitonic_sort_parallel(&mut v, threads),
        );
        t.row(vec![
            threads.to_string(),
            fmt_ms(m.median_ms()),
            format!("{:.2}x", seq / m.median_ms()),
        ]);
        records.push(
            BenchRecord::new("cpu_sorts", "bitonic-parallel", "uniform", "u32", n)
                .with_timing(&m)
                .with_extra("threads", threads)
                .with_extra("speedup_vs_serial", seq / m.median_ms()),
        );
    }
    println!("{}", t.render());

    // --- odd-even network contrast (E7 flavour) ---------------------------
    println!("== network baselines, n = 64K (odd-even is O(n²) comparators) ==");
    let n = 1 << 16;
    let mut t = Table::new(vec!["network", "median ms"]);
    let nets: Vec<(&str, &str, Box<dyn FnMut(Vec<u32>)>)> = vec![
        ("bitonic", "bitonic-scalar", Box::new(|mut v: Vec<u32>| bitonic_sort(&mut v))),
        ("odd-even", "odd-even", Box::new(|mut v: Vec<u32>| oddeven_sort(&mut v))),
    ];
    for (name, slug, mut f) in nets {
        let m = bench.run_with_setup(name, || gen.u32s(n, Distribution::Uniform), &mut f);
        t.row(vec![name.to_string(), fmt_ms(m.median_ms())]);
        records.push(BenchRecord::new("cpu_sorts", slug, "uniform", "u32", n).with_timing(&m));
    }
    println!("{}", t.render());

    Trajectory::append_default_or_exit(records);
}
