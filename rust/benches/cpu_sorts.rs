//! CPU substrate shoot-out (DESIGN.md E1/E2/E9): every from-scratch sort
//! vs the std library across distributions, plus the multicore bitonic
//! scaling study the paper lists as future work (§6).

use bitonic_tpu::bench::Bench;
use bitonic_tpu::sort::{
    bitonic_sort, bitonic_sort_parallel, heapsort, mergesort, oddeven_sort, quicksort,
    radix_sort_u32,
};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let bench = Bench::quick();
    let mut gen = Generator::new(0xC0DE);
    let n = 1 << 20;

    // --- all sorts on uniform u32 ---------------------------------------
    println!("== CPU sorts, n = {} uniform u32 ==", fmt_size(n));
    let mut t = Table::new(vec!["algorithm", "median ms", "vs std"]);
    let std_ms = bench
        .run_with_setup("std", || gen.u32s(n, Distribution::Uniform), |mut v| {
            v.sort_unstable()
        })
        .median_ms();
    let algos: Vec<(&str, Box<dyn FnMut(Vec<u32>)>)> = vec![
        ("std sort_unstable", Box::new(|mut v: Vec<u32>| v.sort_unstable())),
        ("quicksort (ours)", Box::new(|mut v: Vec<u32>| quicksort(&mut v))),
        ("heapsort", Box::new(|mut v: Vec<u32>| heapsort(&mut v))),
        ("mergesort", Box::new(|mut v: Vec<u32>| mergesort(&mut v))),
        ("radix (LSD)", Box::new(|mut v: Vec<u32>| radix_sort_u32(&mut v))),
        ("bitonic (seq)", Box::new(|mut v: Vec<u32>| bitonic_sort(&mut v))),
        ("bitonic (4 thr)", Box::new(|mut v: Vec<u32>| bitonic_sort_parallel(&mut v, 4))),
    ];
    for (name, mut f) in algos {
        let m = bench.run_with_setup(name, || gen.u32s(n, Distribution::Uniform), &mut f);
        t.row(vec![
            name.to_string(),
            fmt_ms(m.median_ms()),
            format!("{:.2}x", m.median_ms() / std_ms),
        ]);
    }
    println!("{}", t.render());

    // --- quicksort vs distributions (adversarial robustness) -------------
    println!("== quicksort robustness across distributions, n = 1M ==");
    let mut t = Table::new(vec!["distribution", "quick ms", "bitonic ms"]);
    for d in Distribution::ALL {
        let q = bench
            .run_with_setup("q", || gen.u32s(n, d), |mut v| quicksort(&mut v))
            .median_ms();
        let b = bench
            .run_with_setup("b", || gen.u32s(n, d), |mut v| bitonic_sort(&mut v))
            .median_ms();
        t.row(vec![d.name().to_string(), fmt_ms(q), fmt_ms(b)]);
    }
    println!("{}", t.render());
    println!("→ bitonic is distribution-oblivious (data-independent network); quicksort varies.\n");

    // --- multicore bitonic scaling (paper §6 future work, E9) ------------
    println!("== multicore bitonic scaling, n = 4M (paper §6 future work) ==");
    let n = 4 << 20;
    let seq = bench
        .run_with_setup("seq", || gen.u32s(n, Distribution::Uniform), |mut v| {
            bitonic_sort(&mut v)
        })
        .median_ms();
    let mut t = Table::new(vec!["threads", "median ms", "speedup"]);
    t.row(vec!["1 (seq)".to_string(), fmt_ms(seq), "1.00x".to_string()]);
    for threads in [2usize, 4, 8, 16] {
        let m = bench.run_with_setup(
            "par",
            || gen.u32s(n, Distribution::Uniform),
            |mut v| bitonic_sort_parallel(&mut v, threads),
        );
        t.row(vec![
            threads.to_string(),
            fmt_ms(m.median_ms()),
            format!("{:.2}x", seq / m.median_ms()),
        ]);
    }
    println!("{}", t.render());

    // --- odd-even network contrast (E7 flavour) ---------------------------
    println!("== network baselines, n = 64K (odd-even is O(n²) comparators) ==");
    let n = 1 << 16;
    let mut t = Table::new(vec!["network", "median ms"]);
    for (name, f) in [
        ("bitonic", Box::new(|mut v: Vec<u32>| bitonic_sort(&mut v)) as Box<dyn FnMut(Vec<u32>)>),
        ("odd-even", Box::new(|mut v: Vec<u32>| oddeven_sort(&mut v))),
    ] {
        let mut f = f;
        let m = bench.run_with_setup(name, || gen.u32s(n, Distribution::Uniform), &mut f);
        t.row(vec![name.to_string(), fmt_ms(m.median_ms())]);
    }
    println!("{}", t.render());
}
