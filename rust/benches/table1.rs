//! Regenerates the paper's Table 1 (DESIGN.md E1–E4) — the complete
//! benchmark: measured CPU columns, calibrated-simulator GPU columns, and
//! the speedup ratio, against the paper's printed values.
//!
//! Sizes ≤ 4M are measured with repetition via the harness; larger CPU
//! sizes run once (they take seconds each and the paper's own numbers are
//! single-run). Set BENCH_TABLE1_FULL=1 to measure through 256M (needs
//! ~8 GiB RAM and several minutes). Measured CPU points are appended to
//! the unified bench trajectory with the simulator prediction and the
//! paper's printed ratio as extras.

use std::time::Instant;

use bitonic_tpu::bench::{Bench, BenchRecord, Trajectory};
use bitonic_tpu::sim::{calibrate_from_table1, PAPER_TABLE1};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{bitonic_sort, quicksort};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let full = std::env::var("BENCH_TABLE1_FULL").is_ok();
    let cap = if full { 256 << 20 } else { 16 << 20 };
    let rep_cap = 4 << 20; // repeated measurement below this
    let cal = calibrate_from_table1();
    let bench = Bench::quick();

    println!("== Table 1 reproduction (paper: Mu/Cui/Song Table 1) ==");
    println!(
        "calibration: t_launch={:.2}µs bw_eff={:.0}GB/s; CPU cap {} (BENCH_TABLE1_FULL=1 for 256M)\n",
        cal.device.t_launch * 1e6,
        cal.device.bw_gmem / 1e9,
        fmt_size(cap)
    );

    let mut t = Table::new(vec![
        "Array size",
        "Quick(cpu)",
        "Bitonic(cpu)",
        "Basic(sim)",
        "Semi(sim)",
        "Opt(sim)",
        "Ratio",
        "paper:Ratio",
        "Δratio",
    ]);
    let mut gen = Generator::new(0x7AB1E1);
    let mut records: Vec<BenchRecord> = Vec::new();
    for row in PAPER_TABLE1.iter().filter(|r| r.n <= cap) {
        let n = row.n;
        let quick_ms;
        let bitonic_ms;
        if n <= rep_cap {
            let m = bench.run_with_setup(
                "quick",
                || gen.u32s(n, Distribution::Uniform),
                |mut v| quicksort(&mut v),
            );
            quick_ms = m.median_ms();
            let m = bench.run_with_setup(
                "bitonic",
                || gen.u32s(n, Distribution::Uniform),
                |mut v| bitonic_sort(&mut v),
            );
            bitonic_ms = m.median_ms();
        } else {
            let data = gen.u32s(n, Distribution::Uniform);
            let mut q = data.clone();
            let t0 = Instant::now();
            quicksort(&mut q);
            quick_ms = t0.elapsed().as_secs_f64() * 1e3;
            let mut b = data;
            let t0 = Instant::now();
            bitonic_sort(&mut b);
            bitonic_ms = t0.elapsed().as_secs_f64() * 1e3;
        }
        let opt = cal.predict_ms(Variant::Optimized, n);
        let ratio = quick_ms / opt;
        for (substrate, ms) in [("quicksort", quick_ms), ("bitonic-scalar", bitonic_ms)] {
            let mut rec = BenchRecord::new("table1", substrate, "uniform", "u32", n)
                .with_ms(ms)
                .with_extra("sim_optimized_ms", opt);
            if substrate == "quicksort" {
                rec = rec.with_extra("ratio_vs_sim_optimized", ratio);
                if let Some(paper) = row.ratio {
                    rec = rec.with_extra("paper_ratio", paper);
                }
            }
            records.push(rec);
        }
        t.row(vec![
            fmt_size(n),
            fmt_ms(quick_ms),
            fmt_ms(bitonic_ms),
            fmt_ms(cal.predict_ms(Variant::Basic, n)),
            fmt_ms(cal.predict_ms(Variant::Semi, n)),
            fmt_ms(opt),
            format!("{ratio:.1}"),
            row.ratio.map(|r| format!("{r:.1}")).unwrap_or("—".into()),
            row.ratio
                .map(|r| format!("{:+.0}%", (ratio - r) / r * 100.0))
                .unwrap_or("—".into()),
        ]);
        eprintln!("  done {}", fmt_size(n));
    }
    println!("{}", t.render());

    // The paper's two headline claims (§Abstract).
    println!("shape assertions:");
    let mut ok = true;
    for row in PAPER_TABLE1.iter().filter(|r| r.n <= cap) {
        let b = cal.predict_ms(Variant::Basic, row.n);
        let s = cal.predict_ms(Variant::Semi, row.n);
        let o = cal.predict_ms(Variant::Optimized, row.n);
        if !(b > s && s > o) {
            println!("  ✗ ordering violated at {}", fmt_size(row.n));
            ok = false;
        }
    }
    println!(
        "  {} Basic > Semi > Optimized at every size",
        if ok { "✓" } else { "✗" }
    );

    Trajectory::append_default_or_exit(records);
}
