//! Ablation study (DESIGN.md E7): the two optimizations in isolation and
//! combination — on the calibrated simulator (the paper's setting) and on
//! the **real native executor**, whose `ExecutionPlan` now compiles the
//! same `Network::launches` fusion the simulator charges for — plus the
//! shared-tile block-size sweep.
//!
//! "semi" = optimization 1 only; "optimized" = 1 + 2. Optimization 2 alone
//! (double-steps without the shared-memory stage) is also modelled here by
//! a custom schedule to complete the 2×2 grid.
//!
//! Run time-bounded (`timeout --signal=KILL 300`) from scripts/verify.sh
//! and CI, like the coordinator smoke: a hang fails loudly.

use bitonic_tpu::bench::{black_box, Bench};
use bitonic_tpu::runtime::{
    spawn_device_host_with, ArtifactKind, ExecutionPlan, HostConfig, Key, PlanConfig,
    DEFAULT_PLAN_BLOCK,
};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::{Network, Variant};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

/// Launch count for "optimization 2 only": every step from global memory,
/// but strides paired two-at-a-time (no shared-memory stage).
fn opt2_only_launches(n: usize) -> usize {
    let mut count = 0;
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 2 {
            count += 1; // double step (j, j/2)
            j /= 4;
        }
        if j == 1 {
            count += 1; // leftover single
        }
        k *= 2;
    }
    count
}

fn main() {
    let cal = calibrate_from_table1();
    let n = 16 << 20;

    // --- 2×2 optimization grid (simulator) -------------------------------
    println!("== ablation: optimization grid at n=16M (calibrated sim) ==");
    let basic = simulate(&cal.device, Variant::Basic, n, 4);
    let semi = simulate(&cal.device, Variant::Semi, n, 4);
    let opt = simulate(&cal.device, Variant::Optimized, n, 4);
    // opt2-only: launch count from the paired global schedule; every
    // launch is one global pass (same cost form as Basic).
    let o2_launches = opt2_only_launches(n);
    let o2_ms = {
        let passes = o2_launches as f64;
        let pass_bytes = 2.0 * (n * 4) as f64;
        (o2_launches as f64 * cal.device.t_launch
            + passes * pass_bytes / cal.device.bw_gmem
            + basic.t_alu)
            * 1e3
    };
    let mut t = Table::new(vec!["configuration", "launches", "ms", "vs basic"]);
    for (name, launches, ms) in [
        ("basic (none)", basic.launches, basic.total_ms()),
        ("opt1 only (semi)", semi.launches, semi.total_ms()),
        ("opt2 only (paired global)", o2_launches, o2_ms),
        ("opt1+opt2 (optimized)", opt.launches, opt.total_ms()),
    ] {
        t.row(vec![
            name.to_string(),
            launches.to_string(),
            fmt_ms(ms),
            format!("{:.2}x", basic.total_ms() / ms),
        ]);
    }
    println!("{}", t.render());
    println!("→ opt1 dominates (pass count k(k+1)/2 → ~2k+presort); opt2 compounds on the remaining global steps.\n");

    // --- block-size sweep (simulator) ------------------------------------
    println!("== shared-tile size sweep at n=16M (sim, optimized schedule) ==");
    let net = Network::new(n);
    let mut t = Table::new(vec!["block", "launches", "ms (sim)"]);
    for log_b in [8u32, 10, 12, 13, 14, 16] {
        let block = 1usize << log_b;
        let launches = net.launches(Variant::Optimized, block).len();
        let mut dev = cal.device;
        // Model: block beyond 4096 u32 keys exceeds K10's 48 KiB shared
        // memory — flag it rather than pretend.
        let fits = block * 4 * 2 <= dev.shmem_bytes;
        dev.shmem_bytes = dev.shmem_bytes.max(block * 8);
        let ms = {
            let r = simulate(&dev, Variant::Optimized, n, 4);
            // simulate() derives block from the device; recompute with the
            // explicit block by scaling the launch/gmem terms.
            let scale = launches as f64 / r.launches as f64;
            ((r.t_launch + r.t_gmem) * scale + r.t_shmem + r.t_alu) * 1e3
        };
        t.row(vec![
            format!("{}{}", fmt_size(block), if fits { "" } else { " (!>48KiB)" }),
            launches.to_string(),
            fmt_ms(ms),
        ]);
    }
    println!("{}", t.render());

    // --- real-executor ablation: fused launch programs -------------------
    // The native executor compiles ExecutionPlan from Network::launches,
    // so Basic/Semi/Optimized here are the *actual* execution schedules —
    // not a cost model. Expected on n >= 16K rows: Optimized >= Semi >=
    // Basic rows/sec, tracking the full-row memory-pass reduction.
    println!("== real-executor ablation: fused plans at block={DEFAULT_PLAN_BLOCK} ==");
    {
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1A);
        let mut t = Table::new(vec![
            "(B,N)", "variant", "hbm passes", "ms / batch", "rows/sec", "vs basic",
        ]);
        for (b, n) in [(8usize, 1usize << 14), (2, 1 << 16)] {
            let mut basic_ms = f64::NAN;
            for v in Variant::ALL {
                let plan = ExecutionPlan::with_config(
                    ArtifactKind::Sort,
                    n,
                    false,
                    PlanConfig { variant: v, block: DEFAULT_PLAN_BLOCK },
                );
                // One instrumented row: the passes actually executed must
                // equal the plan's static count (same assert as the tests).
                let mut probe = gen.u32s(n, Distribution::Uniform);
                assert_eq!(plan.run_row_counting(&mut probe), plan.global_passes());
                let meas = bench.run_with_setup(
                    v.name(),
                    || gen.u32s(b * n, Distribution::Uniform),
                    |mut rows| {
                        for row in rows.chunks_mut(n) {
                            plan.run_row(row);
                        }
                        black_box(rows);
                    },
                );
                let ms = meas.median_ms();
                if v == Variant::Basic {
                    basic_ms = ms;
                }
                t.row(vec![
                    format!("({b},{})", fmt_size(n)),
                    v.name().to_string(),
                    plan.global_passes().to_string(),
                    fmt_ms(ms),
                    format!("{:.0}", b as f64 / (ms / 1e3)),
                    format!("{:.2}x", basic_ms / ms),
                ]);
            }
        }
        println!("{}", t.render());
        println!("→ the paper's ordering, measured on the real plan walk: fewer");
        println!("  full-row passes ⇒ more rows/sec (opt1 fuses the in-block tail,");
        println!("  opt2 halves the remaining global passes).\n");
    }

    // --- device-host path: same ablation end to end ----------------------
    // Three hosts over the same fixture artifact, differing only in
    // HostConfig::plan — registry, host thread and row-pool included.
    println!("== device-host path ablation (fixture artifact, 4 threads) ==");
    {
        let dir = bitonic_tpu::runtime::default_artifacts_dir();
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1B);
        let mut t = Table::new(vec!["artifact", "plan", "ms / batch", "rows/sec"]);
        let mut ok = true;
        for v in Variant::ALL {
            let host = spawn_device_host_with(
                &dir,
                HostConfig {
                    threads: 4,
                    plan: PlanConfig { variant: v, block: DEFAULT_PLAN_BLOCK },
                },
            );
            let (handle, manifest) = match host {
                Ok(hm) => hm,
                Err(e) => {
                    println!("   (skipped: {e:#})");
                    ok = false;
                    break;
                }
            };
            let meta = manifest
                .size_classes(Variant::Optimized)
                .into_iter()
                .max_by_key(|m| m.n)
                .expect("fixture menu empty")
                .clone();
            let key = Key::of(&meta);
            let (b, n) = (meta.batch, meta.n);
            let meas = bench.run_with_setup(
                v.name(),
                || gen.u32s(b * n, Distribution::Uniform),
                |rows| {
                    let _ = handle.sort_u32(key, rows).unwrap();
                },
            );
            t.row(vec![
                format!("{} ({b},{})", meta.name, fmt_size(n)),
                v.name().to_string(),
                fmt_ms(meas.median_ms()),
                format!("{:.0}", b as f64 / (meas.median_ms() / 1e3)),
            ]);
            handle.shutdown();
        }
        if ok {
            println!("{}", t.render());
        }
    }
}
