//! Ablation study (DESIGN.md E7): the two optimizations in isolation and
//! combination — on the calibrated simulator (the paper's setting) and on
//! the real measured artifact path (PJRT CPU, interpret-mode Pallas) —
//! plus the shared-tile block-size sweep.
//!
//! "semi" = optimization 1 only; "optimized" = 1 + 2. Optimization 2 alone
//! (double-steps without the shared-memory stage) is also modelled here by
//! a custom schedule to complete the 2×2 grid.

use bitonic_tpu::bench::Bench;
use bitonic_tpu::runtime::{spawn_device_host, Dtype, Key};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::{Network, Variant};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

/// Launch count for "optimization 2 only": every step from global memory,
/// but strides paired two-at-a-time (no shared-memory stage).
fn opt2_only_launches(n: usize) -> usize {
    let mut count = 0;
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 2 {
            count += 1; // double step (j, j/2)
            j /= 4;
        }
        if j == 1 {
            count += 1; // leftover single
        }
        k *= 2;
    }
    count
}

fn main() {
    let cal = calibrate_from_table1();
    let n = 16 << 20;

    // --- 2×2 optimization grid (simulator) -------------------------------
    println!("== ablation: optimization grid at n=16M (calibrated sim) ==");
    let basic = simulate(&cal.device, Variant::Basic, n, 4);
    let semi = simulate(&cal.device, Variant::Semi, n, 4);
    let opt = simulate(&cal.device, Variant::Optimized, n, 4);
    // opt2-only: launch count from the paired global schedule; every
    // launch is one global pass (same cost form as Basic).
    let o2_launches = opt2_only_launches(n);
    let o2_ms = {
        let passes = o2_launches as f64;
        let pass_bytes = 2.0 * (n * 4) as f64;
        (o2_launches as f64 * cal.device.t_launch
            + passes * pass_bytes / cal.device.bw_gmem
            + basic.t_alu)
            * 1e3
    };
    let mut t = Table::new(vec!["configuration", "launches", "ms", "vs basic"]);
    for (name, launches, ms) in [
        ("basic (none)", basic.launches, basic.total_ms()),
        ("opt1 only (semi)", semi.launches, semi.total_ms()),
        ("opt2 only (paired global)", o2_launches, o2_ms),
        ("opt1+opt2 (optimized)", opt.launches, opt.total_ms()),
    ] {
        t.row(vec![
            name.to_string(),
            launches.to_string(),
            fmt_ms(ms),
            format!("{:.2}x", basic.total_ms() / ms),
        ]);
    }
    println!("{}", t.render());
    println!("→ opt1 dominates (pass count k(k+1)/2 → ~2k+presort); opt2 compounds on the remaining global steps.\n");

    // --- block-size sweep (simulator) ------------------------------------
    println!("== shared-tile size sweep at n=16M (sim, optimized schedule) ==");
    let net = Network::new(n);
    let mut t = Table::new(vec!["block", "launches", "ms (sim)"]);
    for log_b in [8u32, 10, 12, 13, 14, 16] {
        let block = 1usize << log_b;
        let launches = net.launches(Variant::Optimized, block).len();
        let mut dev = cal.device;
        // Model: block beyond 4096 u32 keys exceeds K10's 48 KiB shared
        // memory — flag it rather than pretend.
        let fits = block * 4 * 2 <= dev.shmem_bytes;
        dev.shmem_bytes = dev.shmem_bytes.max(block * 8);
        let ms = {
            let r = simulate(&dev, Variant::Optimized, n, 4);
            // simulate() derives block from the device; recompute with the
            // explicit block by scaling the launch/gmem terms.
            let scale = launches as f64 / r.launches as f64;
            ((r.t_launch + r.t_gmem) * scale + r.t_shmem + r.t_alu) * 1e3
        };
        t.row(vec![
            format!("{}{}", fmt_size(block), if fits { "" } else { " (!>48KiB)" }),
            launches.to_string(),
            fmt_ms(ms),
        ]);
    }
    println!("{}", t.render());

    // --- measured artifact ablation (real executions) --------------------
    println!("== measured artifact path (native-CPU executor) ==");
    println!("   NOTE: the offline executor runs the same network for every");
    println!("   variant — these rows sanity-check the execution path, not the");
    println!("   paper's variant ordering (needs the PJRT backend).");
    match spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir()) {
        Ok((handle, manifest)) => {
            let bench = Bench::quick();
            let mut gen = Generator::new(0xAB1A);
            let mut t = Table::new(vec!["(B,N)", "basic", "semi", "optimized"]);
            for meta in manifest.size_classes(Variant::Basic) {
                let (b, nn) = (meta.batch, meta.n);
                if b != 8 {
                    continue;
                }
                let mut cells = Vec::new();
                for v in Variant::ALL {
                    let Some(m) = manifest.find(v, b, nn, Dtype::U32, false) else {
                        continue;
                    };
                    let key = Key::of(m);
                    let _ = handle.sort_u32(key, gen.u32s(b * nn, Distribution::Uniform));
                    let meas = bench.run_with_setup(
                        v.name(),
                        || gen.u32s(b * nn, Distribution::Uniform),
                        |rows| {
                            let _ = handle.sort_u32(key, rows).unwrap();
                        },
                    );
                    cells.push(fmt_ms(meas.median_ms()));
                }
                if cells.len() == 3 {
                    t.row(vec![
                        format!("({b},{})", fmt_size(nn)),
                        cells[0].clone(),
                        cells[1].clone(),
                        cells[2].clone(),
                    ]);
                }
            }
            println!("{}", t.render());
        }
        Err(e) => println!("   (skipped: {e:#})"),
    }
}
