//! Ablation study (DESIGN.md E7): the two optimizations in isolation and
//! combination — on the calibrated simulator (the paper's setting) and on
//! the **real native executor**, whose `ExecutionPlan` now compiles the
//! same `Network::launches` fusion the simulator charges for — plus the
//! shared-tile block-size sweep, the batch-interleaved execution sweep,
//! and an autotune smoke.
//!
//! "semi" = optimization 1 only; "optimized" = 1 + 2. Optimization 2 alone
//! (double-steps without the shared-memory stage) is also modelled here by
//! a custom schedule to complete the 2×2 grid.
//!
//! Every real-executor measurement is also recorded into
//! `BENCH_ablation.json` at the current directory (repo root when run via
//! scripts/verify.sh; override with `$BENCH_ABLATION_JSON`) so future PRs
//! can diff against a recorded trajectory instead of re-deriving
//! baselines from prose — and appended to the unified
//! `BENCH_trajectory.json` (see `bitonic_tpu::bench::record`) so the
//! `report` subcommand sees them alongside the matrix sweep.
//!
//! Run time-bounded (`timeout --signal=KILL 300`) from scripts/verify.sh
//! and CI, like the coordinator smoke: a hang fails loudly.

use std::time::Duration;

use bitonic_tpu::bench::{black_box, Bench, BenchRecord, Trajectory};
use bitonic_tpu::runtime::{
    effective_interleave, spawn_device_host_with, tune, ArtifactKind, Dtype, ExecutionPlan,
    HostConfig, Key, PlanConfig, TuneRequest, DEFAULT_PLAN_BLOCK,
};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::{Network, Variant};
use bitonic_tpu::sort::{KernelChoice, KernelIsa, SortKey};
use bitonic_tpu::util::json::Json;
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

/// Launch count for "optimization 2 only": every step from global memory,
/// but strides paired two-at-a-time (no shared-memory stage).
fn opt2_only_launches(n: usize) -> usize {
    let mut count = 0;
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 2 {
            count += 1; // double step (j, j/2)
            j /= 4;
        }
        if j == 1 {
            count += 1; // leftover single
        }
        k *= 2;
    }
    count
}

/// Common fields of one bench-trajectory entry (callers append extras) —
/// single point of truth for the JSON schema future PRs diff against.
fn trajectory_entry(b: usize, n: usize, variant: &str, block: usize, interleave: usize, ms: f64) -> Json {
    let mut e = Json::obj();
    e.set("b", b)
        .set("n", n)
        .set("variant", variant)
        .set("block", block)
        .set("interleave", interleave)
        .set("ms_per_batch", ms)
        .set("rows_per_sec", b as f64 / (ms / 1e3));
    e
}

/// Uniform keys for the explicit-SIMD ablation, one fn per dtype so the
/// sweep macro takes a plain path (`sweep_dtype!("u32", simd_keys_u32)`).
fn simd_keys_u32(g: &mut Generator, len: usize) -> Vec<u32> {
    g.u32s(len, Distribution::Uniform)
}

/// Order-preserving u32 → i32 cast (flip the sign bit) — the same
/// mapping the survey matrix uses for its i32 column.
fn simd_keys_i32(g: &mut Generator, len: usize) -> Vec<i32> {
    g.u32s(len, Distribution::Uniform)
        .into_iter()
        .map(|x| (x ^ 0x8000_0000) as i32)
        .collect()
}

fn simd_keys_f32(g: &mut Generator, len: usize) -> Vec<f32> {
    g.f32s(len, Distribution::Uniform)
}

fn main() {
    let cal = calibrate_from_table1();
    let n = 16 << 20;
    // The machine-readable trajectory this bench leaves behind.
    let mut report = Json::obj();
    report.set("bench", "ablation");
    // Plus the unified cross-bench trajectory (schema-validated records).
    let mut records: Vec<BenchRecord> = Vec::new();

    // --- 2×2 optimization grid (simulator) -------------------------------
    println!("== ablation: optimization grid at n=16M (calibrated sim) ==");
    let basic = simulate(&cal.device, Variant::Basic, n, 4);
    let semi = simulate(&cal.device, Variant::Semi, n, 4);
    let opt = simulate(&cal.device, Variant::Optimized, n, 4);
    // opt2-only: launch count from the paired global schedule; every
    // launch is one global pass (same cost form as Basic).
    let o2_launches = opt2_only_launches(n);
    let o2_ms = {
        let passes = o2_launches as f64;
        let pass_bytes = 2.0 * (n * 4) as f64;
        (o2_launches as f64 * cal.device.t_launch
            + passes * pass_bytes / cal.device.bw_gmem
            + basic.t_alu)
            * 1e3
    };
    let mut t = Table::new(vec!["configuration", "launches", "ms", "vs basic"]);
    for (name, launches, ms) in [
        ("basic (none)", basic.launches, basic.total_ms()),
        ("opt1 only (semi)", semi.launches, semi.total_ms()),
        ("opt2 only (paired global)", o2_launches, o2_ms),
        ("opt1+opt2 (optimized)", opt.launches, opt.total_ms()),
    ] {
        t.row(vec![
            name.to_string(),
            launches.to_string(),
            fmt_ms(ms),
            format!("{:.2}x", basic.total_ms() / ms),
        ]);
    }
    println!("{}", t.render());
    println!("→ opt1 dominates (pass count k(k+1)/2 → ~2k+presort); opt2 compounds on the remaining global steps.\n");

    // --- block-size sweep (simulator) ------------------------------------
    println!("== shared-tile size sweep at n=16M (sim, optimized schedule) ==");
    let net = Network::new(n);
    let mut t = Table::new(vec!["block", "launches", "ms (sim)"]);
    for log_b in [8u32, 10, 12, 13, 14, 16] {
        let block = 1usize << log_b;
        let launches = net.launches(Variant::Optimized, block).len();
        let mut dev = cal.device;
        // Model: block beyond 4096 u32 keys exceeds K10's 48 KiB shared
        // memory — flag it rather than pretend.
        let fits = block * 4 * 2 <= dev.shmem_bytes;
        dev.shmem_bytes = dev.shmem_bytes.max(block * 8);
        let ms = {
            let r = simulate(&dev, Variant::Optimized, n, 4);
            // simulate() derives block from the device; recompute with the
            // explicit block by scaling the launch/gmem terms.
            let scale = launches as f64 / r.launches as f64;
            ((r.t_launch + r.t_gmem) * scale + r.t_shmem + r.t_alu) * 1e3
        };
        t.row(vec![
            format!("{}{}", fmt_size(block), if fits { "" } else { " (!>48KiB)" }),
            launches.to_string(),
            fmt_ms(ms),
        ]);
    }
    println!("{}", t.render());

    // --- real-executor ablation: fused launch programs -------------------
    // The native executor compiles ExecutionPlan from Network::launches,
    // so Basic/Semi/Optimized here are the *actual* execution schedules —
    // not a cost model. Expected on n >= 16K rows: Optimized >= Semi >=
    // Basic rows/sec, tracking the full-row memory-pass reduction.
    println!("== real-executor ablation: fused plans at block={DEFAULT_PLAN_BLOCK} ==");
    {
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1A);
        let mut entries = Json::arr();
        let mut t = Table::new(vec![
            "(B,N)", "variant", "hbm passes", "ms / batch", "rows/sec", "vs basic",
        ]);
        for (b, n) in [(8usize, 1usize << 14), (2, 1 << 16)] {
            let mut basic_ms = f64::NAN;
            for v in Variant::ALL {
                let plan = ExecutionPlan::with_config(
                    ArtifactKind::Sort,
                    n,
                    false,
                    PlanConfig {
                        variant: v,
                        block: DEFAULT_PLAN_BLOCK,
                        interleave: 1,
                        ..Default::default()
                    },
                );
                // One instrumented row: the passes actually executed must
                // equal the plan's static count (same assert as the tests).
                let mut probe = gen.u32s(n, Distribution::Uniform);
                assert_eq!(plan.run_row_counting(&mut probe), plan.global_passes());
                let meas = bench.run_with_setup(
                    v.name(),
                    || gen.u32s(b * n, Distribution::Uniform),
                    |mut rows| {
                        for row in rows.chunks_mut(n) {
                            plan.run_row(row);
                        }
                        black_box(rows);
                    },
                );
                let ms = meas.median_ms();
                if v == Variant::Basic {
                    basic_ms = ms;
                }
                let rows_per_sec = b as f64 / (ms / 1e3);
                t.row(vec![
                    format!("({b},{})", fmt_size(n)),
                    v.name().to_string(),
                    plan.global_passes().to_string(),
                    fmt_ms(ms),
                    format!("{:.0}", rows_per_sec),
                    format!("{:.2}x", basic_ms / ms),
                ]);
                let mut e = trajectory_entry(b, n, v.name(), DEFAULT_PLAN_BLOCK, 1, ms);
                e.set("hbm_passes", plan.global_passes())
                    .set("speedup_vs_basic", basic_ms / ms);
                entries.push(e);
                records.push(
                    BenchRecord::new("ablation", "bitonic-plan", "uniform", "u32", n)
                        .with_batch(b)
                        .with_timing(&meas)
                        .with_extra("variant", v.name())
                        .with_extra("hbm_passes", plan.global_passes())
                        .with_extra("speedup_vs_basic", basic_ms / ms),
                );
            }
        }
        println!("{}", t.render());
        println!("→ the paper's ordering, measured on the real plan walk: fewer");
        println!("  full-row passes ⇒ more rows/sec (opt1 fuses the in-block tail,");
        println!("  opt2 halves the remaining global passes).\n");
        report.set("plan_ablation", entries);
    }

    // --- batch-interleaved ablation: the n=64K acceptance sweep ----------
    // Scalar Optimized (interleave 1 — exactly the PR 3 path) vs the
    // batch-interleaved mode at several (block, R) on a 16-row batch of
    // n=64K rows, serial plan walk (no pool), so the delta is purely the
    // SIMT-style lane parallelism + its transpose tax. Bit-exactness with
    // the scalar path is asserted inline on every config before timing.
    println!("== batch-interleaved ablation at (16, 64K), serial plan walk ==");
    {
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1C);
        let (b, n) = (16usize, 1usize << 16);
        let mut entries = Json::arr();
        let mut t = Table::new(vec![
            "config", "block", "R", "ms / batch", "rows/sec", "vs scalar",
        ]);
        let run_tiles = |plan: &ExecutionPlan, rows: &mut [u32], r: usize| {
            let mut scratch = Vec::new();
            for tile in rows.chunks_mut(r * n) {
                plan.run_tile(tile, &mut scratch);
            }
        };
        let mk = |block, interleave| {
            ExecutionPlan::with_config(
                ArtifactKind::Sort,
                n,
                false,
                PlanConfig { block, interleave, ..Default::default() },
            )
        };
        // Correctness reference + scalar baseline.
        let reference_rows = gen.u32s(b * n, Distribution::DupHeavy);
        let scalar_plan = mk(DEFAULT_PLAN_BLOCK, 1);
        let mut reference = reference_rows.clone();
        run_tiles(&scalar_plan, &mut reference, 1);
        let scalar_meas = bench.run_with_setup(
            "scalar",
            || gen.u32s(b * n, Distribution::Uniform),
            |mut rows| {
                run_tiles(&scalar_plan, &mut rows, 1);
                black_box(rows);
            },
        );
        let scalar_ms = scalar_meas.median_ms();
        t.row(vec![
            "scalar (PR 3 path)".into(),
            DEFAULT_PLAN_BLOCK.to_string(),
            "1".into(),
            fmt_ms(scalar_ms),
            format!("{:.0}", b as f64 / (scalar_ms / 1e3)),
            "1.00x".into(),
        ]);
        let mut e = trajectory_entry(b, n, "optimized", DEFAULT_PLAN_BLOCK, 1, scalar_ms);
        e.set("speedup_vs_scalar", 1.0f64);
        entries.push(e);
        records.push(
            BenchRecord::new("ablation", "bitonic-interleaved", "uniform", "u32", n)
                .with_batch(b)
                .with_timing(&scalar_meas)
                .with_extra("block", DEFAULT_PLAN_BLOCK)
                .with_extra("interleave", 1usize)
                .with_extra("speedup_vs_scalar", 1.0f64),
        );
        let mut best_speedup = 1.0f64;
        for (block, r) in [
            (DEFAULT_PLAN_BLOCK, 4usize),
            (DEFAULT_PLAN_BLOCK, 8),
            (DEFAULT_PLAN_BLOCK, 16),
            (1024, 8),
            (1024, 16),
        ] {
            let plan = mk(block, r);
            // Bit-exactness before timing: interleaved == scalar result.
            let mut check = reference_rows.clone();
            run_tiles(&plan, &mut check, r);
            assert_eq!(check, reference, "interleaved diverged at block={block} R={r}");
            let meas = bench.run_with_setup(
                "interleaved",
                || gen.u32s(b * n, Distribution::Uniform),
                |mut rows| {
                    run_tiles(&plan, &mut rows, r);
                    black_box(rows);
                },
            );
            let ms = meas.median_ms();
            let speedup = scalar_ms / ms;
            best_speedup = best_speedup.max(speedup);
            t.row(vec![
                "interleaved".into(),
                block.to_string(),
                r.to_string(),
                fmt_ms(ms),
                format!("{:.0}", b as f64 / (ms / 1e3)),
                format!("{speedup:.2}x"),
            ]);
            let mut e = trajectory_entry(b, n, "optimized", block, r, ms);
            e.set("speedup_vs_scalar", speedup);
            entries.push(e);
            records.push(
                BenchRecord::new("ablation", "bitonic-interleaved", "uniform", "u32", n)
                    .with_batch(b)
                    .with_timing(&meas)
                    .with_extra("block", block)
                    .with_extra("interleave", r)
                    .with_extra("speedup_vs_scalar", speedup),
            );
        }
        println!("{}", t.render());
        println!("→ acceptance target: best interleaved config ≥ 2.00x the scalar path");
        println!("  (best measured: {best_speedup:.2}x)\n");
        report.set("interleaved_ablation", entries);
        report.set("interleaved_speedup_vs_scalar", best_speedup);
        report.set("interleaved_speedup_target_met", best_speedup >= 2.0);
    }

    // --- explicit-SIMD ablation: comparator ISA vs autovec ---------------
    // Identical launch program and interleaved tile walk per cell; ONLY
    // the comparator ISA changes (PlanConfig::kernel). `scalar` is the
    // autovectorizer's best shot at the plain kernels — the baseline the
    // autovec-vs-explicit question is asked against — `portable` the
    // chunked swap-free form, and `avx2` the explicit intrinsics (present
    // only under `--features simd` on a host that has AVX2). Bit-
    // exactness against the scalar ISA is asserted on every cell before
    // timing: total-order equivalence position by position, which for
    // these dtypes is exactly bit equality.
    println!("== explicit-SIMD ablation: comparator ISA vs autovec ==");
    {
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1E);
        let isas = KernelIsa::available_isas();
        let mut entries = Json::arr();
        let mut t = Table::new(vec![
            "dtype", "(B,N)", "R", "isa", "ms / batch", "rows/sec", "vs autovec",
        ]);
        let mut best = 1.0f64;
        macro_rules! sweep_dtype {
            ($dtype:literal, $make:expr) => {
                // R matches the batch so each class runs as one tile with
                // at least one full AVX2 vector of lanes (width 8).
                for (b, n, r) in [(16usize, 1usize << 16, 16usize), (8, 1 << 18, 8)] {
                    let mk = |isa| {
                        ExecutionPlan::with_config(
                            ArtifactKind::Sort,
                            n,
                            false,
                            PlanConfig {
                                interleave: r,
                                kernel: KernelChoice::Fixed(isa),
                                ..Default::default()
                            },
                        )
                    };
                    let run_tiles = |plan: &ExecutionPlan, rows: &mut Vec<_>, scr: &mut Vec<_>| {
                        for tile in rows.chunks_mut(r * n) {
                            plan.run_tile(tile, scr);
                        }
                    };
                    let mut scratch = Vec::new();
                    let fixture = ($make)(&mut gen, b * n);
                    let mut reference = fixture.clone();
                    run_tiles(&mk(KernelIsa::Scalar), &mut reference, &mut scratch);
                    let mut autovec_ms = f64::NAN;
                    for &isa in &isas {
                        let plan = mk(isa);
                        let mut check = fixture.clone();
                        run_tiles(&plan, &mut check, &mut scratch);
                        let exact = check
                            .iter()
                            .zip(&reference)
                            .all(|(x, y)| !x.total_lt(y) && !y.total_lt(x));
                        assert!(exact, "{} {} diverged from scalar at n={n}", $dtype, isa.name());
                        let meas = bench.run_with_setup(
                            isa.name(),
                            || ($make)(&mut gen, b * n),
                            |mut rows| {
                                run_tiles(&plan, &mut rows, &mut scratch);
                                black_box(rows);
                            },
                        );
                        let ms = meas.median_ms();
                        if isa == KernelIsa::Scalar {
                            autovec_ms = ms;
                        }
                        let speedup = autovec_ms / ms;
                        best = best.max(speedup);
                        t.row(vec![
                            $dtype.to_string(),
                            format!("({b},{})", fmt_size(n)),
                            r.to_string(),
                            isa.name().to_string(),
                            fmt_ms(ms),
                            format!("{:.0}", b as f64 / (ms / 1e3)),
                            format!("{speedup:.2}x"),
                        ]);
                        let mut e = trajectory_entry(b, n, "optimized", DEFAULT_PLAN_BLOCK, r, ms);
                        e.set("dtype", $dtype)
                            .set("isa", isa.name())
                            .set("simd_speedup_vs_autovec", speedup);
                        entries.push(e);
                        records.push(
                            BenchRecord::new("ablation", "bitonic-simd", "uniform", $dtype, n)
                                .with_batch(b)
                                .with_timing(&meas)
                                .with_extra("isa", isa.name())
                                .with_extra("interleave", r)
                                .with_extra("simd_speedup_vs_autovec", speedup),
                        );
                    }
                }
            };
        }
        sweep_dtype!("u32", simd_keys_u32);
        sweep_dtype!("i32", simd_keys_i32);
        sweep_dtype!("f32", simd_keys_f32);
        println!("{}", t.render());
        println!("→ simd_speedup_vs_autovec ≥ 1.30x on any cell meets the ISSUE gate; if no");
        println!("  cell reaches it the explicit kernels are refuted on this host (autovec");
        println!("  already saturates) and the tune sweep below should keep choosing scalar.");
        println!("  best measured: {best:.2}x over {} ISA(s)\n", isas.len());
        report.set("simd_ablation", entries);
        report.set("simd_best_speedup_vs_autovec", best);
        report.set("simd_target_met", best >= 1.3);
    }

    // --- autotune smoke: the sweep the `tune` CLI runs, one class -------
    // Records the per-host chosen config for the same n=64K class so the
    // trajectory ties measured ablation numbers to what the autotuner
    // would actually pick on this machine — including which comparator
    // ISA it settles on (the autovec-vs-explicit question, answered per
    // host by measurement rather than assumption).
    println!("== autotune smoke: chosen config for (65536, uint32) ==");
    {
        let request = TuneRequest {
            classes: vec![(1 << 16, Dtype::U32)],
            blocks: vec![1024, DEFAULT_PLAN_BLOCK],
            interleaves: vec![1, 8, 16],
            threads: vec![1],
            isas: KernelIsa::available_isas(),
            rows: 8,
            bench: Bench {
                warmup: 1,
                min_iters: 2,
                max_iters: 6,
                target: Duration::from_millis(200),
            },
            seed: 0xAB1D,
        };
        let outcome = tune(&request);
        let chosen = &outcome.profile.entries[0];
        println!(
            "chosen: block={} interleave={} isa={} ({:.0} rows/sec over {} candidates)\n",
            chosen.block,
            chosen.interleave,
            chosen.isa.name(),
            chosen.rows_per_sec,
            outcome.measured.len()
        );
        let mut e = Json::obj();
        e.set("n", chosen.n)
            .set("dtype", chosen.dtype.name())
            .set("variant", chosen.variant.name())
            .set("block", chosen.block)
            .set("interleave", chosen.interleave)
            .set("threads", chosen.threads)
            .set("isa", chosen.isa.name())
            .set("rows_per_sec", chosen.rows_per_sec)
            .set("candidates_measured", outcome.measured.len());
        report.set("autotune_smoke", e);
    }

    // --- device-host path: same ablation end to end ----------------------
    // Three hosts over the same fixture artifact, differing only in
    // HostConfig::plan — registry, host thread and row-pool included.
    println!("== device-host path ablation (fixture artifact, 4 threads) ==");
    {
        let dir = bitonic_tpu::runtime::default_artifacts_dir();
        let bench = Bench::quick();
        let mut gen = Generator::new(0xAB1B);
        let mut entries = Json::arr();
        let mut t = Table::new(vec!["artifact", "plan", "R", "ms / batch", "rows/sec"]);
        let mut ok = true;
        // The three fusion variants scalar (the launch-program ablation),
        // plus the default interleaved Optimized config end to end.
        let configs: Vec<(Variant, usize)> = Variant::ALL
            .into_iter()
            .map(|v| (v, 1usize))
            .chain([(Variant::Optimized, 8usize)])
            .collect();
        for (v, interleave) in configs {
            let host = spawn_device_host_with(
                &dir,
                HostConfig {
                    threads: 4,
                    plan: PlanConfig {
                        variant: v,
                        block: DEFAULT_PLAN_BLOCK,
                        interleave,
                        ..Default::default()
                    }
                    .into(),
                },
            );
            let (handle, manifest) = match host {
                Ok(hm) => hm,
                Err(e) => {
                    println!("   (skipped: {e:#})");
                    ok = false;
                    break;
                }
            };
            // Scalar variant rows keep the max-n artifact (continuity
            // with the PR 3 trajectory); the interleaved row needs rows
            // to interleave, so it takes the max-batch artifact instead
            // (the max-n fixture artifact has B = 1).
            let meta = manifest
                .size_classes(Variant::Optimized)
                .into_iter()
                .max_by_key(|m| if interleave > 1 { m.batch } else { m.n })
                .expect("fixture menu empty")
                .clone();
            let key = Key::of(&meta);
            let (b, n) = (meta.batch, meta.n);
            let meas = bench.run_with_setup(
                v.name(),
                || gen.u32s(b * n, Distribution::Uniform),
                |rows| {
                    let _ = handle.sort_u32(key, rows).unwrap();
                },
            );
            let ms = meas.median_ms();
            t.row(vec![
                format!("{} ({b},{})", meta.name, fmt_size(n)),
                v.name().to_string(),
                interleave.to_string(),
                fmt_ms(ms),
                format!("{:.0}", b as f64 / (ms / 1e3)),
            ]);
            let mut e = trajectory_entry(b, n, v.name(), DEFAULT_PLAN_BLOCK, interleave, ms);
            // The executor narrows the configured width so all 4 pool
            // workers get a tile; record what actually ran alongside the
            // configured R so the trajectory is not mislabeled.
            e.set("artifact", meta.name.as_str())
                .set("threads", 4usize)
                .set("interleave_effective", effective_interleave(interleave, b, 4));
            entries.push(e);
            handle.shutdown();
        }
        if ok {
            println!("{}", t.render());
            report.set("device_host", entries);
        }
    }

    // --- persist the trajectories ----------------------------------------
    let path = std::env::var("BENCH_ABLATION_JSON").unwrap_or_else(|_| "BENCH_ablation.json".into());
    std::fs::write(&path, report.render()).expect("writing bench trajectory");
    println!("wrote bench trajectory to {path}");

    Trajectory::append_default_or_exit(records);
}
