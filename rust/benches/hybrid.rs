//! Out-of-core hybrid sorter benchmark: device-chunk sort + bitonic merge
//! tree vs the pure-CPU baselines, at sizes beyond the largest artifact
//! row — the deployment scenario for a fixed-shape sorting accelerator.
//!
//! Absolute device times are XLA-CPU interpret-mode emulation; the
//! interesting outputs are the stage statistics (how much work lands on
//! the device vs the CPU tail) and the chunk-size ablation. Measurements
//! are appended to the unified bench trajectory with the stage
//! statistics as extras.

use bitonic_tpu::bench::{Bench, BenchRecord, Trajectory};
use bitonic_tpu::runtime::spawn_device_host;
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{quicksort, HybridSorter};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let Ok((handle, manifest)) = spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir()) else {
        println!("SKIP: no artifacts — run `python -m compile.aot` first");
        return;
    };
    if manifest.merge_classes().is_empty() {
        println!("SKIP: no merge artifacts (quick mode?)");
        return;
    }
    let bench = Bench::quick();
    let mut gen = Generator::new(0xB12D);
    let mut records: Vec<BenchRecord> = Vec::new();

    // --- hybrid vs CPU at 2x..8x the largest artifact row ----------------
    println!("== hybrid (device chunks + merge tree) vs CPU quicksort ==");
    let sorter = HybridSorter::new(handle.clone(), &manifest, Variant::Optimized).unwrap();
    let chunk = sorter.chunk();
    let mut t = Table::new(vec![
        "n", "quicksort ms", "hybrid ms", "dev sorts", "dev merges", "cpu merges",
    ]);
    for mult in [2usize, 4, 8] {
        let n = chunk * mult + 321;
        let qm = bench.run_with_setup("q", || gen.u32s(n, Distribution::Uniform), |mut v| {
            quicksort(&mut v)
        });
        let q = qm.median_ms();
        let mut last_stats = None;
        let hm = bench.run_with_setup(
            "h",
            || gen.u32s(n, Distribution::Uniform),
            |mut v| {
                last_stats = Some(sorter.sort(&mut v).unwrap());
            },
        );
        let h = hm.median_ms();
        let s = last_stats.unwrap();
        t.row(vec![
            fmt_size(n),
            fmt_ms(q),
            fmt_ms(h),
            s.device_sorts.to_string(),
            s.device_merges.to_string(),
            s.cpu_merges.to_string(),
        ]);
        records.push(BenchRecord::new("hybrid", "quicksort", "uniform", "u32", n).with_timing(&qm));
        records.push(
            BenchRecord::new("hybrid", "hybrid", "uniform", "u32", n)
                .with_timing(&hm)
                .with_extra("chunk", chunk)
                .with_extra("device_sorts", s.device_sorts)
                .with_extra("device_merges", s.device_merges)
                .with_extra("cpu_merges", s.cpu_merges)
                .with_extra("speedup_vs_quicksort", q / h),
        );
    }
    println!("{}", t.render());

    // --- chunk-size ablation ---------------------------------------------
    println!("== chunk-size ablation (n = 128K + 77) ==");
    let n = (128 << 10) + 77;
    let mut t = Table::new(vec![
        "chunk", "hybrid ms", "dev sorts", "dev merges", "cpu merges",
    ]);
    for chunk in [1024usize, 4096, 16384, 65536] {
        let Ok(sorter) =
            HybridSorter::with_chunk(handle.clone(), &manifest, Variant::Optimized, chunk)
        else {
            continue;
        };
        let mut last_stats = None;
        let hm = bench.run_with_setup(
            "h",
            || gen.u32s(n, Distribution::Uniform),
            |mut v| {
                last_stats = Some(sorter.sort(&mut v).unwrap());
            },
        );
        let h = hm.median_ms();
        let s = last_stats.unwrap();
        t.row(vec![
            fmt_size(chunk),
            fmt_ms(h),
            s.device_sorts.to_string(),
            s.device_merges.to_string(),
            s.cpu_merges.to_string(),
        ]);
        records.push(
            BenchRecord::new("hybrid", "hybrid", "uniform", "u32", n)
                .with_timing(&hm)
                .with_extra("chunk", chunk)
                .with_extra("device_sorts", s.device_sorts)
                .with_extra("device_merges", s.device_merges)
                .with_extra("cpu_merges", s.cpu_merges),
        );
    }
    println!("{}", t.render());
    println!("→ bigger chunks shift work from the merge tree into the chunk sort; the");
    println!("  crossover depends on the device's sort-vs-merge throughput ratio.");

    Trajectory::append_default_or_exit(records);
}
