//! Scaling series (DESIGN.md E6): Table 1 rendered as the two figures the
//! paper implies — time vs n per variant, and speedup ratio vs n (the
//! ratio "hump" peaking near 2^18) — plus the *measured* end-to-end device
//! path (PJRT CPU, interpret-mode kernels) for the artifact sizes, which
//! validates the relative variant ordering on real executions. Measured
//! points are appended to the unified bench trajectory.

use bitonic_tpu::bench::{Bench, BenchRecord, Trajectory};
use bitonic_tpu::runtime::{spawn_device_host, Key};
use bitonic_tpu::sim::{calibrate_from_table1, PAPER_TABLE1};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::quicksort;
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let cal = calibrate_from_table1();
    let mut records: Vec<BenchRecord> = Vec::new();

    // --- figure A: simulated time vs n, per variant ---------------------
    println!("== figure A: GPU time vs n (calibrated model; paper cols for reference) ==");
    let mut t = Table::new(vec![
        "n", "Basic", "Semi", "Optimized", "paper:Basic", "paper:Semi", "paper:Opt",
    ]);
    for row in &PAPER_TABLE1 {
        t.row(vec![
            fmt_size(row.n),
            fmt_ms(cal.predict_ms(Variant::Basic, row.n)),
            fmt_ms(cal.predict_ms(Variant::Semi, row.n)),
            fmt_ms(cal.predict_ms(Variant::Optimized, row.n)),
            fmt_ms(row.gpu_basic),
            fmt_ms(row.gpu_semi),
            fmt_ms(row.gpu_optimized),
        ]);
    }
    println!("{}", t.render());

    // --- figure B: speedup ratio vs n -----------------------------------
    println!("== figure B: speedup ratio (cpu quick / gpu optimized) vs n ==");
    let bench = Bench::quick();
    let mut gen = Generator::new(0x5CA1E);
    let mut t = Table::new(vec!["n", "ratio(ours)", "ratio(paper)"]);
    for row in PAPER_TABLE1.iter().filter(|r| r.n <= 16 << 20) {
        let n = row.n;
        let m = bench.run_with_setup(
            "quick",
            || gen.u32s(n, Distribution::Uniform),
            |mut v| quicksort(&mut v),
        );
        let sim_opt = cal.predict_ms(Variant::Optimized, n);
        let ratio = m.median_ms() / sim_opt;
        t.row(vec![
            fmt_size(n),
            format!("{ratio:.1}"),
            row.ratio.map(|r| format!("{r:.1}")).unwrap_or("—".into()),
        ]);
        let mut rec = BenchRecord::new("scaling", "quicksort", "uniform", "u32", n)
            .with_timing(&m)
            .with_extra("sim_optimized_ms", sim_opt)
            .with_extra("ratio_vs_sim_optimized", ratio);
        if let Some(paper) = row.ratio {
            rec = rec.with_extra("paper_ratio", paper);
        }
        records.push(rec);
    }
    println!("{}", t.render());

    // --- figure C: measured device path (artifacts, native executor) ----
    println!("== figure C: measured artifact execution (native-CPU executor) ==");
    println!("   NOTE: the offline executor runs the same network for every");
    println!("   variant, so the per-variant columns measure executor overhead");
    println!("   only — variant ordering becomes meaningful once the PJRT");
    println!("   backend is vendored (see runtime::executor docs).");
    match spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir()) {
        Ok((handle, manifest)) => {
            let mut t =
                Table::new(vec!["(B,N)", "basic ms", "semi ms", "optimized ms", "opt/basic"]);
            // All (batch, n) shapes present for every variant.
            let shapes: Vec<(usize, usize)> = manifest
                .size_classes(Variant::Basic)
                .iter()
                .map(|m| (m.batch, m.n))
                .collect();
            for (b, n) in shapes {
                let mut ms = Vec::new();
                for v in Variant::ALL {
                    let Some(meta) = manifest.find(v, b, n, bitonic_tpu::runtime::Dtype::U32, false)
                    else {
                        continue;
                    };
                    let key = Key::of(meta);
                    // warm (compile) outside timing
                    let rows = gen.u32s(b * n, Distribution::Uniform);
                    let _ = handle.sort_u32(key, rows).unwrap();
                    let m = bench.run_with_setup(
                        v.name(),
                        || gen.u32s(b * n, Distribution::Uniform),
                        |rows| {
                            let _ = handle.sort_u32(key, rows).unwrap();
                        },
                    );
                    records.push(
                        BenchRecord::new("scaling", "bitonic-executor", "uniform", "u32", n)
                            .with_batch(b)
                            .with_timing(&m)
                            .with_extra("artifact", meta.name.as_str())
                            .with_extra("variant", v.name()),
                    );
                    ms.push(m.median_ms());
                }
                if ms.len() == 3 {
                    t.row(vec![
                        format!("({b},{})", fmt_size(n)),
                        fmt_ms(ms[0]),
                        fmt_ms(ms[1]),
                        fmt_ms(ms[2]),
                        format!("{:.2}", ms[2] / ms[0]),
                    ]);
                }
            }
            println!("{}", t.render());
        }
        Err(e) => println!("   (skipped: {e:#} — run `python -m compile.aot`)"),
    }

    Trajectory::append_default_or_exit(records);
}
